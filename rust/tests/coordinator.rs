//! The threaded coordinator must be **bit-identical** to the single-process
//! driver: same seed ⇒ same trajectory, same bits — for every method.

use std::sync::Arc;

use shiftcomp::algorithms::{Algorithm, DcgdShift, ShiftRule, RunOpts};
use shiftcomp::compressors::{Compressor, Identity, NaturalDithering, RandK, TopK, ValPrec};
use shiftcomp::coordinator::{ClusterConfig, DistributedRunner, MethodKind};
use shiftcomp::net::LinkModel;
use shiftcomp::problems::{Problem, Ridge};

fn ridge() -> Arc<Ridge> {
    Arc::new(Ridge::paper_default(3))
}

fn assert_trajectories_match(
    mut single: DcgdShift,
    mut dist: DistributedRunner,
    p: &dyn Problem,
    rounds: usize,
) {
    let mut bits_single = 0u64;
    let mut bits_dist = 0u64;
    for k in 0..rounds {
        bits_single += single.step(p).bits_up;
        bits_dist += dist.step(p).bits_up;
        let xs = single.x();
        let xd = dist.x();
        assert_eq!(xs, xd, "iterates diverged at round {k}");
    }
    assert_eq!(bits_single, bits_dist, "bit accounting diverged");
}

#[test]
fn dcgd_bit_identical() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let single = DcgdShift::dcgd(p.as_ref(), RandK::with_q(d, 0.3), 11);
    let gamma = single.gamma;
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.3)) as Box<dyn Compressor>)
        .collect();
    let dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Fixed,
            gamma,
            prec: ValPrec::F64,
            seed: 11,
            links: None,
            resync_every: 0,
            local_steps: 1,
            pipeline: false,
            downlink: None,
            uplink_ef: false,
            ..Default::default()
        },
    );
    assert_trajectories_match(single, dist, p.as_ref(), 60);
}

#[test]
fn diana_bit_identical() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let single = DcgdShift::diana(p.as_ref(), NaturalDithering::l2(d, 4), None, 13);
    let gamma = single.gamma;
    // recover alpha from theory exactly as the constructor does
    let omega = NaturalDithering::l2(d, 4).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(NaturalDithering::l2(d, 4)) as Box<dyn Compressor>)
        .collect();
    let dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Diana {
                alpha: ss.alpha,
                with_c: false,
            },
            gamma,
            prec: ValPrec::F64,
            seed: 13,
            links: None,
            resync_every: 0,
            local_steps: 1,
            pipeline: false,
            downlink: None,
            uplink_ef: false,
            ..Default::default()
        },
    );
    assert_trajectories_match(single, dist, p.as_ref(), 60);
}

#[test]
fn diana_with_c_bit_identical() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let c: Box<dyn Compressor> = Box::new(TopK::with_q(d, 0.5));
    let single = DcgdShift::diana(p.as_ref(), RandK::with_q(d, 0.3), Some(c.clone_box()), 15);
    let gamma = single.gamma;
    let omega = RandK::with_q(d, 0.3).omega().unwrap();
    let delta = c.delta().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![delta; n], 2.0);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.3)) as Box<dyn Compressor>)
        .collect();
    let cs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(TopK::with_q(d, 0.5)) as Box<dyn Compressor>)
        .collect();
    let dist = DistributedRunner::new(
        p.clone(),
        qs,
        Some(cs),
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Diana {
                alpha: ss.alpha,
                with_c: true,
            },
            gamma,
            prec: ValPrec::F64,
            seed: 15,
            links: None,
            resync_every: 0,
            local_steps: 1,
            pipeline: false,
            downlink: None,
            uplink_ef: false,
            ..Default::default()
        },
    );
    assert_trajectories_match(single, dist, p.as_ref(), 50);
}

#[test]
fn rand_diana_bit_identical() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let single = DcgdShift::rand_diana(p.as_ref(), RandK::with_q(d, 0.2), Some(0.2), 17);
    let gamma = single.gamma;
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.2)) as Box<dyn Compressor>)
        .collect();
    let dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::RandDiana { p: 0.2 },
            gamma,
            prec: ValPrec::F64,
            seed: 17,
            links: None,
            resync_every: 0,
            local_steps: 1,
            pipeline: false,
            downlink: None,
            uplink_ef: false,
            ..Default::default()
        },
    );
    assert_trajectories_match(single, dist, p.as_ref(), 80);
}

#[test]
fn star_bit_identical() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let single = DcgdShift::star(p.as_ref(), RandK::with_q(d, 0.4), None, 19);
    let gamma = single.gamma;
    let shifts: Vec<Vec<f64>> = (0..n).map(|i| p.grad_star(i).to_vec()).collect();
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.4)) as Box<dyn Compressor>)
        .collect();
    let dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        shifts,
        ClusterConfig {
            method: MethodKind::Star { with_c: false },
            gamma,
            prec: ValPrec::F64,
            seed: 19,
            links: None,
            resync_every: 0,
            local_steps: 1,
            pipeline: false,
            downlink: None,
            uplink_ef: false,
            ..Default::default()
        },
    );
    assert_trajectories_match(single, dist, p.as_ref(), 60);
}

#[test]
fn network_accounting_reflects_straggler() {
    let p = ridge();
    let n = p.n_workers();
    let d = p.dim();
    // one worker 100× slower
    let mut links = vec![
        LinkModel {
            up_bps: 1e9,
            down_bps: 1e9,
            latency: 0.0,
        };
        n
    ];
    links[n - 1].up_bps = 1e7;
    let mut runner = DistributedRunner::rand_diana(
        p.clone(),
        RandK::with_q(d, 0.5),
        None,
        21,
        Some(links),
    );
    for _ in 0..20 {
        runner.step(p.as_ref());
    }
    let slow_time = runner.simulated_time();

    let fast_links = vec![
        LinkModel {
            up_bps: 1e9,
            down_bps: 1e9,
            latency: 0.0,
        };
        n
    ];
    let mut fast = DistributedRunner::rand_diana(
        p.clone(),
        RandK::with_q(d, 0.5),
        None,
        21,
        Some(fast_links),
    );
    for _ in 0..20 {
        fast.step(p.as_ref());
    }
    assert!(
        slow_time > fast.simulated_time() * 10.0,
        "straggler must dominate: {slow_time} vs {}",
        fast.simulated_time()
    );
}

#[test]
fn distributed_runner_survives_many_rounds() {
    let p = ridge();
    let d = p.dim();
    let mut runner = DistributedRunner::diana(p.clone(), RandK::with_q(d, 0.5), 23, None);
    let trace = runner.run(
        p.as_ref(),
        &RunOpts {
            max_rounds: 500,
            tol: 0.0,
            record_every: 50,
            ..Default::default()
        },
    );
    assert_eq!(trace.rounds(), 501);
    assert!(!trace.diverged);
}

// ------------------------------------------------- delta downlink protocol

/// Periodic dense resync frames must not perturb the trajectory: the
/// resync carries exactly the master iterate, so a cluster resyncing every
/// 3 rounds stays bit-identical to the single-process driver (which has no
/// replicas at all).
#[test]
fn resync_rounds_stay_bit_identical() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let single = DcgdShift::diana(p.as_ref(), RandK::with_q(d, 0.3), None, 31);
    let gamma = single.gamma;
    let omega = RandK::with_q(d, 0.3).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.3)) as Box<dyn Compressor>)
        .collect();
    let dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Diana {
                alpha: ss.alpha,
                with_c: false,
            },
            gamma,
            prec: ValPrec::F64,
            seed: 31,
            links: None,
            resync_every: 3,
            local_steps: 1,
            pipeline: false,
            downlink: None,
            uplink_ef: false,
            ..Default::default()
        },
    );
    assert_trajectories_match(single, dist, p.as_ref(), 40);
}

/// `set_x0` mid-run replaces the master iterate out of band; the next
/// broadcast must resync the worker replicas or every later round diverges.
#[test]
fn set_x0_mid_run_resyncs_replicas() {
    let p = ridge();
    let d = p.dim();
    let mut single = DcgdShift::diana(p.as_ref(), RandK::with_q(d, 0.4), None, 33);
    let gamma = single.gamma;
    let n = p.n_workers();
    let omega = RandK::with_q(d, 0.4).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.4)) as Box<dyn Compressor>)
        .collect();
    let mut dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Diana {
                alpha: ss.alpha,
                with_c: false,
            },
            gamma,
            prec: ValPrec::F64,
            seed: 33,
            links: None,
            resync_every: 0,
            local_steps: 1,
            pipeline: false,
            downlink: None,
            uplink_ef: false,
            ..Default::default()
        },
    );
    for _ in 0..5 {
        single.step(p.as_ref());
        dist.step(p.as_ref());
    }
    // out-of-band drift: both drivers jump to a fresh iterate
    let x_new: Vec<f64> = (0..d).map(|j| 0.25 * (j as f64 + 1.0)).collect();
    single.set_x0(x_new.clone());
    dist.set_x0(x_new);
    for k in 0..20 {
        single.step(p.as_ref());
        dist.step(p.as_ref());
        assert_eq!(single.x(), dist.x(), "diverged {k} rounds after set_x0");
    }
}

/// Downlink accounting is measured, not assumed: after the round-0 resync,
/// a sparse-aggregate method's broadcast must cost O(nnz) — far below the
/// dense d·prec bits/worker of the old protocol — and the resync round
/// itself must cost about the dense size.
#[test]
fn downlink_bits_drop_to_nnz_after_resync() {
    let p = ridge(); // d = 80, n = 10
    let d = p.dim();
    let n = p.n_workers();
    // plain DCGD: zero shifts, so g^k is the ≤ n·K-sparse Rand-K union
    let mut runner = DistributedRunner::dcgd(p.clone(), RandK::new(d, 2), 35, None);
    let dense_bits_per_worker = d as u64 * 64;
    let s0 = runner.step(p.as_ref());
    assert!(
        s0.bits_down >= n as u64 * dense_bits_per_worker,
        "round 0 must ship a dense resync: {} bits",
        s0.bits_down
    );
    let mut max_later = 0u64;
    for _ in 0..10 {
        let s = runner.step(p.as_ref());
        max_later = max_later.max(s.bits_down);
    }
    // union of 10 workers × K=2 ⇒ ≤ 20 coords at ~72 bits/coord + frame
    // overhead: far under half the dense 5120 bits/worker
    assert!(
        max_later < n as u64 * dense_bits_per_worker / 2,
        "steady-state delta frames too large: {max_later} bits"
    );
    // and with a K = 1 fleet the delta is tiny
    let mut runner = DistributedRunner::dcgd(p.clone(), RandK::new(d, 1), 36, None);
    runner.step(p.as_ref());
    let s = runner.step(p.as_ref());
    assert!(
        s.bits_down < n as u64 * dense_bits_per_worker / 4,
        "K=1 delta should be near-empty: {} bits",
        s.bits_down
    );
}

/// An f32-precision cluster: deltas are quantized on the wire, but master
/// and replicas still apply identical updates, so the run converges and the
/// trajectory stays reproducible.
#[test]
fn f32_wire_precision_cluster_converges() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let omega = RandK::with_q(d, 0.5).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let mk = || {
        let qs: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| Box::new(RandK::with_q(d, 0.5)) as Box<dyn Compressor>)
            .collect();
        DistributedRunner::new(
            p.clone(),
            qs,
            None,
            vec![vec![0.0; d]; n],
            ClusterConfig {
                method: MethodKind::Diana {
                    alpha: ss.alpha,
                    with_c: false,
                },
                gamma: ss.gamma,
                prec: ValPrec::F32,
                seed: 37,
                links: None,
                resync_every: 50,
                local_steps: 1,
                pipeline: false,
                downlink: None,
                uplink_ef: false,
                ..Default::default()
            },
        )
    };
    let mut a = mk();
    let mut b = mk();
    for k in 0..120 {
        a.step(p.as_ref());
        b.step(p.as_ref());
        assert_eq!(a.x(), b.x(), "f32 cluster not reproducible at round {k}");
    }
    let err = shiftcomp::linalg::dist_sq(a.x(), p.x_star())
        / shiftcomp::linalg::dist_sq(&shiftcomp::algorithms::paper_x0(d, 37), p.x_star());
    assert!(
        err.is_finite() && err < 2.0,
        "f32 cluster diverged: rel err {err}"
    );
}

/// With `resync_every = 0` the single-process driver's downlink accounting
/// mirrors the runner frame for frame: the round-0 dense bootstrap resync,
/// then each round the delta frame built the round before.
#[test]
fn downlink_accounting_mirrors_runner() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let mut single = DcgdShift::diana(p.as_ref(), RandK::with_q(d, 0.2), None, 39);
    let gamma = single.gamma;
    let omega = RandK::with_q(d, 0.2).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.2)) as Box<dyn Compressor>)
        .collect();
    let mut dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Diana {
                alpha: ss.alpha,
                with_c: false,
            },
            gamma,
            prec: ValPrec::F64,
            seed: 39,
            links: None,
            resync_every: 0,
            local_steps: 1,
            pipeline: false,
            downlink: None,
            uplink_ef: false,
            ..Default::default()
        },
    );
    for k in 0..30 {
        let a = single.step(p.as_ref());
        let b = dist.step(p.as_ref());
        assert_eq!(a.bits_down, b.bits_down, "downlink accounting at round {k}");
        assert_eq!(a.bits_up, b.bits_up, "uplink accounting at round {k}");
    }
}

// ------------------------------------------- error-fed-back (EF) downlink

/// EF downlink with the identity compressor drops nothing: trajectories
/// and downlink bit accounting are bit-identical to the exact delta path
/// (and therefore to the single-process driver).
#[test]
fn ef_identity_downlink_bit_identical_to_exact() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let mut single = DcgdShift::diana(p.as_ref(), RandK::with_q(d, 0.3), None, 41);
    let gamma = single.gamma;
    let omega = RandK::with_q(d, 0.3).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.3)) as Box<dyn Compressor>)
        .collect();
    let mut dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Diana {
                alpha: ss.alpha,
                with_c: false,
            },
            gamma,
            prec: ValPrec::F64,
            seed: 41,
            links: None,
            resync_every: 0,
            local_steps: 1,
            pipeline: false,
            downlink: Some(Box::new(shiftcomp::compressors::Identity::new(d))),
            uplink_ef: false,
            ..Default::default()
        },
    );
    for k in 0..40 {
        let a = single.step(p.as_ref());
        let b = dist.step(p.as_ref());
        assert_eq!(single.x(), dist.x(), "iterates diverged at round {k}");
        assert_eq!(a.bits_up, b.bits_up, "uplink bits at round {k}");
        assert_eq!(a.bits_down, b.bits_down, "downlink bits at round {k}");
    }
    // identity keeps the error accumulator exactly zero
    assert!(dist.ef_error().unwrap().iter().all(|&v| v == 0.0));
    // and the single-process EF-identity mirror is also bit-identical
    let mut exact = DcgdShift::diana(p.as_ref(), RandK::with_q(d, 0.3), None, 41);
    let mut ef_single = DcgdShift::diana(p.as_ref(), RandK::with_q(d, 0.3), None, 41)
        .with_downlink(Box::new(shiftcomp::compressors::Identity::new(d)));
    for k in 0..40 {
        let a = exact.step(p.as_ref());
        let b = ef_single.step(p.as_ref());
        assert_eq!(exact.x(), ef_single.x(), "single drivers diverged at round {k}");
        assert_eq!(a.bits_down, b.bits_down, "single bits_down at round {k}");
    }
}

/// Top-K EF downlink: the threaded cluster and the single-process mirror
/// follow the same (lossy-broadcast) trajectory bit for bit, with the same
/// measured bit accounting.
#[test]
fn ef_topk_cluster_matches_single_process_mirror() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let mut single = DcgdShift::diana(p.as_ref(), RandK::with_q(d, 0.3), None, 43)
        .with_downlink(Box::new(TopK::with_q(d, 0.25)));
    let gamma = single.gamma;
    let omega = RandK::with_q(d, 0.3).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.3)) as Box<dyn Compressor>)
        .collect();
    let mut dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Diana {
                alpha: ss.alpha,
                with_c: false,
            },
            gamma,
            prec: ValPrec::F64,
            seed: 43,
            links: None,
            resync_every: 0,
            local_steps: 1,
            pipeline: false,
            downlink: Some(Box::new(TopK::with_q(d, 0.25))),
            uplink_ef: false,
            ..Default::default()
        },
    );
    for k in 0..60 {
        let a = single.step(p.as_ref());
        let b = dist.step(p.as_ref());
        assert_eq!(single.x(), dist.x(), "iterates diverged at round {k}");
        assert_eq!(a.bits_up, b.bits_up, "uplink bits at round {k}");
        assert_eq!(a.bits_down, b.bits_down, "downlink bits at round {k}");
        assert_eq!(
            single.replica(),
            dist.replica_mirror(),
            "replicas diverged at round {k}"
        );
        assert_eq!(
            single.ef_error(),
            dist.ef_error(),
            "EF accumulators diverged at round {k}"
        );
    }
    // the lossy broadcast must not destabilize the run (the conservative
    // theory step makes per-round progress tiny on the ill-conditioned
    // ridge, so pin boundedness here; convergence itself is covered by the
    // long-horizon tests on the exact path)
    let x0 = shiftcomp::algorithms::paper_x0(d, 43);
    let err = shiftcomp::linalg::dist_sq(dist.x(), p.x_star())
        / shiftcomp::linalg::dist_sq(&x0, p.x_star());
    assert!(err.is_finite() && err < 1.5, "EF-TopK run blew up: rel err {err}");
}

/// The EF machinery, observed from inside the cluster: worker replicas are
/// bit-equal to the master's mirror (lagged by the one in-flight frame),
/// the EF invariant x̂ + e = x holds to rounding, the Top-K residual obeys
/// the contraction bound, and a periodic resync restores exact equality.
#[test]
fn ef_topk_invariant_drift_and_resync() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let resync_every = 7usize;
    let delta_contr = TopK::with_q(d, 0.2).delta().unwrap();
    let omega = RandK::with_q(d, 0.4).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.4)) as Box<dyn Compressor>)
        .collect();
    let mut dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Diana {
                alpha: ss.alpha,
                with_c: false,
            },
            gamma: ss.gamma,
            prec: ValPrec::F64,
            seed: 45,
            links: None,
            resync_every,
            local_steps: 1,
            pipeline: false,
            downlink: Some(Box::new(TopK::with_q(d, 0.2))),
            uplink_ef: false,
            ..Default::default()
        },
    );
    let mut prev_mirror: Option<Vec<f64>> = None;
    let mut prev_e: Vec<f64> = vec![0.0; d];
    let mut prev_x: Vec<f64> = dist.x().to_vec();
    for k in 0..30 {
        let is_resync_round = k == 0 || k % resync_every == 0;
        let x_at_resync = dist.x().to_vec();
        dist.step(p.as_ref());
        // worker replicas during round k hold what the mirror held after
        // round k−1 — unless round k resynced, which overwrites them with
        // the master iterate as of the start of the round
        let snap0 = dist.worker_snapshot(0);
        let snap_last = dist.worker_snapshot(n - 1);
        assert_eq!(snap0.x_replica, snap_last.x_replica, "replicas differ at {k}");
        if is_resync_round {
            assert_eq!(
                snap0.x_replica, x_at_resync,
                "round {k}: resync must overwrite replicas with the master iterate"
            );
        } else {
            let expect = prev_mirror.as_ref().expect("non-resync round after round 0");
            assert_eq!(
                &snap0.x_replica, expect,
                "round {k}: worker replica != master mirror (lagged)"
            );
        }
        let mirror = dist.replica_mirror().unwrap().to_vec();
        let e = dist.ef_error().unwrap().to_vec();
        // EF invariant x̂ + e = x, to fp rounding
        let x = dist.x();
        for j in 0..d {
            let lhs = mirror[j] + e[j];
            assert!(
                (lhs - x[j]).abs() <= 1e-9 * x[j].abs().max(1.0),
                "round {k} coord {j}: invariant broken ({lhs} vs {})",
                x[j]
            );
        }
        // contraction: ‖e_k‖² ≤ (1 − δ)‖e_{k−1} + Δ_k‖² (resync flushes
        // e_{k−1} to zero first); small slack for the Δ reconstruction
        let mut u = if is_resync_round { vec![0.0; d] } else { prev_e.clone() };
        for j in 0..d {
            u[j] += x[j] - prev_x[j];
        }
        let bound = (1.0 - delta_contr) * shiftcomp::linalg::nrm2_sq(&u);
        let e_sq = shiftcomp::linalg::nrm2_sq(&e);
        assert!(
            e_sq <= bound * (1.0 + 1e-9) + 1e-18,
            "round {k}: residual {e_sq} above contraction bound {bound}"
        );
        prev_mirror = Some(mirror);
        prev_e = e;
        prev_x = x.to_vec();
    }
}

// ------------------------------------------------ f32 shift-replica parity

/// Headline bugfix: under f32 wire precision the worker's shift h must be
/// bit-equal to the master's replica reconstructed from the quantized wire
/// frames (workers now apply the pre-quantized packet, as the iterate path
/// always has).
#[test]
fn f32_worker_shifts_bit_equal_master_replicas() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let omega = NaturalDithering::l2(d, 4).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(NaturalDithering::l2(d, 4)) as Box<dyn Compressor>)
        .collect();
    let mut dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Diana {
                alpha: ss.alpha,
                with_c: false,
            },
            gamma: ss.gamma,
            prec: ValPrec::F32,
            seed: 47,
            links: None,
            resync_every: 0,
            local_steps: 1,
            pipeline: false,
            downlink: None,
            uplink_ef: false,
            ..Default::default()
        },
    );
    for _ in 0..50 {
        dist.step(p.as_ref());
    }
    for wi in 0..n {
        let snap = dist.worker_snapshot(wi);
        let master = dist.shift(wi);
        for j in 0..d {
            assert_eq!(
                snap.h[j].to_bits(),
                master[j].to_bits(),
                "worker {wi} coord {j}: shift replicas diverged under f32"
            );
        }
    }
    // Rand-DIANA refreshes (always-quantized delta path) stay bit-equal too
    let omega_rk = RandK::with_q(d, 0.3).omega().unwrap();
    let ss_rd = shiftcomp::theory::rand_diana(p.as_ref(), omega_rk, &vec![0.3; n], None);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.3)) as Box<dyn Compressor>)
        .collect();
    let mut dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::RandDiana { p: 0.3 },
            gamma: ss_rd.gamma,
            prec: ValPrec::F32,
            seed: 48,
            links: None,
            resync_every: 0,
            local_steps: 1,
            pipeline: false,
            downlink: None,
            uplink_ef: false,
            ..Default::default()
        },
    );
    for _ in 0..50 {
        dist.step(p.as_ref());
    }
    for wi in 0..n {
        let snap = dist.worker_snapshot(wi);
        assert_eq!(snap.h, dist.shift(wi), "worker {wi} rand-diana shift");
    }
}

/// With shift updates quantized at the source on both drivers, an f32
/// cluster is bit-identical to the f32 single-process driver — iterates,
/// shifts and bit accounting.
#[test]
fn f32_single_process_mirrors_cluster_bit_exactly() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let mut single = DcgdShift::diana(p.as_ref(), RandK::with_q(d, 0.4), None, 49);
    single.prec = ValPrec::F32;
    let gamma = single.gamma;
    let omega = RandK::with_q(d, 0.4).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.4)) as Box<dyn Compressor>)
        .collect();
    let mut dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Diana {
                alpha: ss.alpha,
                with_c: false,
            },
            gamma,
            prec: ValPrec::F32,
            seed: 49,
            links: None,
            resync_every: 0,
            local_steps: 1,
            pipeline: false,
            downlink: None,
            uplink_ef: false,
            ..Default::default()
        },
    );
    for k in 0..60 {
        let a = single.step(p.as_ref());
        let b = dist.step(p.as_ref());
        assert_eq!(single.x(), dist.x(), "f32 iterates diverged at round {k}");
        assert_eq!(a.bits_up, b.bits_up, "f32 uplink bits at round {k}");
        assert_eq!(a.bits_down, b.bits_down, "f32 downlink bits at round {k}");
    }
    for wi in 0..n {
        assert_eq!(single.shift(wi), dist.shift(wi), "f32 shift of worker {wi}");
    }
}

/// `resync_every = 1` semantics, pinned: the round-0 resync is the
/// bootstrap's job (`needs_resync`), periodic dense resyncs cover every
/// later round — so every round is dense (that is what the knob asks
/// for), the trajectory stays bit-identical to the single-process driver,
/// and the accounting reflects dense frames.
#[test]
fn resync_every_round_stays_exact_and_dense() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let mut single = DcgdShift::diana(p.as_ref(), RandK::with_q(d, 0.3), None, 51);
    let gamma = single.gamma;
    let omega = RandK::with_q(d, 0.3).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.3)) as Box<dyn Compressor>)
        .collect();
    let mut dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Diana {
                alpha: ss.alpha,
                with_c: false,
            },
            gamma,
            prec: ValPrec::F64,
            seed: 51,
            links: None,
            resync_every: 1,
            local_steps: 1,
            pipeline: false,
            downlink: None,
            uplink_ef: false,
            ..Default::default()
        },
    );
    let dense_frame_bits = shiftcomp::wire::resync_frame_bits(d);
    for k in 0..20 {
        single.step(p.as_ref());
        let s = dist.step(p.as_ref());
        assert_eq!(single.x(), dist.x(), "diverged at round {k}");
        assert_eq!(
            s.bits_down,
            n as u64 * dense_frame_bits,
            "round {k} must broadcast one dense resync frame per worker"
        );
    }
}

/// A forced resync flushes the EF accumulator: after `set_x0` the replicas
/// re-converge to the master exactly, even mid-flight on a Top-K downlink.
#[test]
fn set_x0_flushes_ef_accumulator() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let omega = RandK::with_q(d, 0.4).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.4)) as Box<dyn Compressor>)
        .collect();
    let mut dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Diana {
                alpha: ss.alpha,
                with_c: false,
            },
            gamma: ss.gamma,
            prec: ValPrec::F64,
            seed: 53,
            links: None,
            resync_every: 0,
            local_steps: 1,
            pipeline: false,
            downlink: Some(Box::new(TopK::with_q(d, 0.1))),
            uplink_ef: false,
            ..Default::default()
        },
    );
    for _ in 0..10 {
        dist.step(p.as_ref());
    }
    assert!(
        dist.ef_error().unwrap().iter().any(|&v| v != 0.0),
        "a K=10% downlink must leave some residual"
    );
    let x_new: Vec<f64> = (0..d).map(|j| 0.5 - 0.01 * j as f64).collect();
    dist.set_x0(x_new.clone());
    dist.step(p.as_ref());
    // the resync round broadcast x_new: every replica holds it exactly
    // and the accumulator was flushed before the round's new fold
    let snap = dist.worker_snapshot(0);
    assert_eq!(snap.x_replica, x_new, "resync must deliver the exact new iterate");
    // invariant holds exactly right after the flush + one fold
    let mirror = dist.replica_mirror().unwrap();
    let e = dist.ef_error().unwrap();
    let x = dist.x();
    for j in 0..d {
        let lhs = mirror[j] + e[j];
        assert!(
            (lhs - x[j]).abs() <= 1e-12 * x[j].abs().max(1.0),
            "coord {j}: {lhs} vs {}",
            x[j]
        );
    }
}

// ------------------------------------------------ local-step batched rounds

#[allow(clippy::too_many_arguments)]
fn mk_batched_cluster(
    p: &Arc<Ridge>,
    method: MethodKind,
    gamma: f64,
    q: f64,
    seed: u64,
    tau: usize,
    pipeline: bool,
    links: Option<Vec<LinkModel>>,
    downlink: Option<Box<dyn Compressor>>,
) -> DistributedRunner {
    let d = p.dim();
    let n = p.n_workers();
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, q)) as Box<dyn Compressor>)
        .collect();
    DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method,
            gamma,
            prec: ValPrec::F64,
            seed,
            links,
            resync_every: 0,
            local_steps: tau,
            pipeline,
            downlink,
            uplink_ef: false,
            ..Default::default()
        },
    )
}

/// The tentpole guarantee: a τ-step batched cluster is bit-identical to
/// the single-process τ-step mirror — iterates, uplink/downlink bit
/// accounting, and (DIANA) the learned shifts, which advance per sub-step
/// on both ends.
#[test]
fn local_steps_cluster_matches_single_process_mirror() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    // DIANA, τ = 4: shifts learn per sub-step
    let mut single =
        DcgdShift::diana(p.as_ref(), RandK::with_q(d, 0.3), None, 61).with_local_steps(4);
    let gamma = single.gamma;
    let omega = RandK::with_q(d, 0.3).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let mut dist = mk_batched_cluster(
        &p,
        MethodKind::Diana {
            alpha: ss.alpha,
            with_c: false,
        },
        gamma,
        0.3,
        61,
        4,
        false,
        None,
        None,
    );
    for k in 0..40 {
        let a = single.step(p.as_ref());
        let b = dist.step(p.as_ref());
        assert_eq!(single.x(), dist.x(), "iterates diverged at round {k}");
        assert_eq!(a.bits_up, b.bits_up, "uplink bits at round {k}");
        assert_eq!(a.bits_down, b.bits_down, "downlink bits at round {k}");
    }
    for wi in 0..n {
        assert_eq!(single.shift(wi), dist.shift(wi), "shift of worker {wi}");
        // the worker's private shift is bit-equal to the master's replica
        // reconstructed from the batched wire frames
        let snap = dist.worker_snapshot(wi);
        assert_eq!(snap.h, dist.shift(wi), "worker {wi} h vs master replica");
    }

    // fixed-shift DCGD, τ = 3
    let mut single =
        DcgdShift::dcgd(p.as_ref(), RandK::with_q(d, 0.25), 63).with_local_steps(3);
    let gamma = single.gamma;
    let mut dist =
        mk_batched_cluster(&p, MethodKind::Fixed, gamma, 0.25, 63, 3, false, None, None);
    for k in 0..40 {
        let a = single.step(p.as_ref());
        let b = dist.step(p.as_ref());
        assert_eq!(single.x(), dist.x(), "dcgd iterates diverged at round {k}");
        assert_eq!(a.bits_up, b.bits_up, "dcgd uplink bits at round {k}");
        assert_eq!(a.bits_down, b.bits_down, "dcgd downlink bits at round {k}");
    }
}

/// `local_steps = 1` is today's wire protocol and trajectory, verbatim:
/// the explicit τ = 1 mirror equals the default-constructed driver frame
/// for frame (all other tests in this file pin the τ = 1 cluster against
/// the default driver already).
#[test]
fn local_steps_one_is_the_per_round_protocol() {
    let p = ridge();
    let d = p.dim();
    let mut base = DcgdShift::diana(p.as_ref(), RandK::with_q(d, 0.3), None, 65);
    let mut tau1 =
        DcgdShift::diana(p.as_ref(), RandK::with_q(d, 0.3), None, 65).with_local_steps(1);
    for k in 0..30 {
        let a = base.step(p.as_ref());
        let b = tau1.step(p.as_ref());
        assert_eq!(base.x(), tau1.x(), "diverged at round {k}");
        assert_eq!(a.bits_up, b.bits_up, "bits_up at round {k}");
        assert_eq!(a.bits_down, b.bits_down, "bits_down at round {k}");
    }
}

/// Pipelining is a pricing model, not an algorithm change: toggling it
/// leaves the trajectory bit-identical (the pipelined-never-exceeds-staged
/// inequality itself is pinned deterministically in `tests/properties.rs`;
/// here the two runs measure compute independently, so their sim clocks
/// are only required to both advance).
#[test]
fn pipelining_is_trajectory_invariant() {
    let p = ridge();
    let n = p.n_workers();
    let omega = RandK::with_q(p.dim(), 0.2).omega().unwrap();
    let ss = shiftcomp::theory::dcgd_fixed(p.as_ref(), &vec![omega; n]);
    let links = vec![LinkModel::default(); n];
    let mut staged = mk_batched_cluster(
        &p,
        MethodKind::Fixed,
        ss.gamma,
        0.2,
        67,
        4,
        false,
        Some(links.clone()),
        None,
    );
    let mut piped = mk_batched_cluster(
        &p,
        MethodKind::Fixed,
        ss.gamma,
        0.2,
        67,
        4,
        true,
        Some(links),
        None,
    );
    for k in 0..25 {
        staged.step(p.as_ref());
        piped.step(p.as_ref());
        assert_eq!(staged.x(), piped.x(), "pipelining changed the trajectory at round {k}");
    }
    assert!(piped.simulated_time() > 0.0);
    assert!(staged.simulated_time() > 0.0);
}

/// Batched rounds compose with the error-fed-back downlink: the EF fold
/// runs once per batch on the composite delta, and the τ-step cluster
/// stays bit-identical to the τ-step single-process mirror — replicas and
/// accumulators included.
#[test]
fn local_steps_ef_downlink_mirror_bit_identical() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let mut single = DcgdShift::diana(p.as_ref(), RandK::with_q(d, 0.3), None, 69)
        .with_downlink(Box::new(TopK::with_q(d, 0.25)))
        .with_local_steps(4);
    let gamma = single.gamma;
    let omega = RandK::with_q(d, 0.3).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let mut dist = mk_batched_cluster(
        &p,
        MethodKind::Diana {
            alpha: ss.alpha,
            with_c: false,
        },
        gamma,
        0.3,
        69,
        4,
        false,
        None,
        Some(Box::new(TopK::with_q(d, 0.25))),
    );
    for k in 0..40 {
        let a = single.step(p.as_ref());
        let b = dist.step(p.as_ref());
        assert_eq!(single.x(), dist.x(), "iterates diverged at round {k}");
        assert_eq!(a.bits_down, b.bits_down, "downlink bits at round {k}");
        assert_eq!(single.replica(), dist.replica_mirror(), "replicas at round {k}");
        assert_eq!(single.ef_error(), dist.ef_error(), "EF accumulators at round {k}");
    }
}

/// The acceptance scenario, as a deterministic-enough test: on a
/// latency-bound link (tiny frames, 50 ms one way), τ = 8 batching +
/// pipelining must cut the simulated wall clock ≥ 3× vs the per-round
/// baseline for the same number of gradient sub-steps.
#[test]
fn local_steps_pipelining_cut_latency_bound_wall_clock() {
    let p = ridge();
    let n = p.n_workers();
    let wan = LinkModel {
        up_bps: 20e6,
        down_bps: 20e6,
        latency: 0.05,
    };
    let omega = RandK::new(p.dim(), 2).omega().unwrap();
    let ss = shiftcomp::theory::dcgd_fixed(p.as_ref(), &vec![omega; n]);
    let mk = |tau: usize, pipeline: bool| {
        let qs: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| Box::new(RandK::new(p.dim(), 2)) as Box<dyn Compressor>)
            .collect();
        DistributedRunner::new(
            p.clone(),
            qs,
            None,
            vec![vec![0.0; p.dim()]; n],
            ClusterConfig {
                method: MethodKind::Fixed,
                gamma: ss.gamma,
                prec: ValPrec::F64,
                seed: 71,
                links: Some(vec![wan; n]),
                resync_every: 0,
                local_steps: tau,
                pipeline,
                downlink: None,
                uplink_ef: false,
                ..Default::default()
            },
        )
    };
    let substeps = 64usize;
    let mut base = mk(1, false);
    for _ in 0..substeps {
        base.step(p.as_ref());
    }
    let mut piped = mk(8, true);
    for _ in 0..substeps / 8 {
        piped.step(p.as_ref());
    }
    let ratio = base.simulated_time() / piped.simulated_time();
    assert!(
        ratio >= 3.0,
        "latency-bound wall clock must collapse ≥ 3×, got {ratio:.2}× \
         ({:.3}s vs {:.3}s)",
        base.simulated_time(),
        piped.simulated_time()
    );
}

/// Local-step batched DCGD still optimizes: on the paper ridge the τ = 4
/// run's relative error keeps shrinking (local steps change the method —
/// x^{k+1} averages the local trajectories — but the shifted-compression
/// step sizes keep it stable).
#[test]
fn local_steps_batched_rounds_make_progress() {
    let p = ridge();
    let d = p.dim();
    let mut alg = DcgdShift::dcgd(p.as_ref(), RandK::with_q(d, 0.25), 73).with_local_steps(4);
    let x0 = shiftcomp::algorithms::paper_x0(d, 73);
    let denom = shiftcomp::linalg::dist_sq(&x0, p.x_star());
    for _ in 0..500 {
        alg.step(p.as_ref());
    }
    let err = shiftcomp::linalg::dist_sq(alg.x(), p.x_star()) / denom;
    assert!(err.is_finite() && err < 0.9, "batched run made no progress: rel err {err}");
}

// --------------------------------------------- error-fed-back (EF) uplink

/// Build a cluster with the EF uplink armed. Per-worker compressors are
/// clones of `q`; `method`/`gamma` are the caller's (Top-K fleets have no
/// ω, so the step comes from `theory::ef_uplink`).
#[allow(clippy::too_many_arguments)]
fn mk_ef_uplink_cluster(
    p: &Arc<Ridge>,
    method: MethodKind,
    gamma: f64,
    q: &(impl Compressor + Clone + 'static),
    seed: u64,
    prec: ValPrec,
    local_steps: usize,
    downlink: Option<Box<dyn Compressor>>,
) -> DistributedRunner {
    let d = p.dim();
    let n = p.n_workers();
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
        .collect();
    DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method,
            gamma,
            prec,
            seed,
            links: None,
            resync_every: 0,
            local_steps,
            pipeline: false,
            downlink,
            uplink_ef: true,
            ..Default::default()
        },
    )
}

/// EF with Identity compressors drops nothing: `e_i` stays exactly zero
/// and both drivers are bit-identical to the exact uplink — trajectory,
/// uplink/downlink bit accounting included.
#[test]
fn ef_uplink_identity_bit_identical_to_exact() {
    let p = ridge();
    let d = p.dim();
    let q = Identity::new(d);
    // single-process: exact vs EF-armed
    let mut exact = DcgdShift::dcgd(p.as_ref(), q.clone(), 81);
    let mut ef = DcgdShift::dcgd(p.as_ref(), q.clone(), 81).with_uplink_ef();
    let gamma = exact.gamma;
    for k in 0..30 {
        let a = exact.step(p.as_ref());
        let b = ef.step(p.as_ref());
        assert_eq!(exact.x(), ef.x(), "single drivers diverged at round {k}");
        assert_eq!(a.bits_up, b.bits_up, "single bits_up at round {k}");
        assert_eq!(a.bits_down, b.bits_down, "single bits_down at round {k}");
    }
    for w in 0..p.n_workers() {
        assert!(
            ef.uplink_error(w).unwrap().iter().all(|&v| v == 0.0),
            "identity EF must keep worker {w}'s accumulator at zero"
        );
    }
    // threaded cluster with EF ≡ the (exact) single-process driver
    let mut exact = DcgdShift::dcgd(p.as_ref(), q.clone(), 81);
    let mut dist = mk_ef_uplink_cluster(
        &p,
        MethodKind::Fixed,
        gamma,
        &q,
        81,
        ValPrec::F64,
        1,
        None,
    );
    for k in 0..30 {
        let a = exact.step(p.as_ref());
        let b = dist.step(p.as_ref());
        assert_eq!(exact.x(), dist.x(), "cluster diverged at round {k}");
        assert_eq!(a.bits_up, b.bits_up, "cluster bits_up at round {k}");
    }
    let snap = dist.worker_snapshot(0);
    let e = snap.uplink_error.expect("EF armed ⇒ snapshot carries e");
    assert!(e.iter().all(|&v| v == 0.0));
}

/// The tentpole guarantee: a Top-K EF uplink cluster is bit-identical to
/// the single-process mirror — iterates, measured bits_up/bits_down,
/// learned shifts, and the worker accumulators themselves (f64 wire).
#[test]
fn ef_uplink_topk_cluster_matches_mirror() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let q = TopK::with_q(d, 0.1);
    let delta = q.delta().unwrap();
    let ss = shiftcomp::theory::ef_uplink(p.as_ref(), &vec![delta; n]);
    let alpha = 0.25; // any shared α pins bit-identity; theory for EF-DIANA is future work
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
        .collect();
    let rules = (0..n)
        .map(|_| ShiftRule::Diana { alpha, c: None })
        .collect();
    let mut single = DcgdShift::custom(
        "diana",
        p.as_ref(),
        qs,
        rules,
        vec![vec![0.0; d]; n],
        ss.gamma,
        83,
    )
    .with_uplink_ef();
    let mut dist = mk_ef_uplink_cluster(
        &p,
        MethodKind::Diana {
            alpha,
            with_c: false,
        },
        ss.gamma,
        &q,
        83,
        ValPrec::F64,
        1,
        None,
    );
    for k in 0..60 {
        let a = single.step(p.as_ref());
        let b = dist.step(p.as_ref());
        assert_eq!(single.x(), dist.x(), "iterates diverged at round {k}");
        assert_eq!(a.bits_up, b.bits_up, "uplink bits at round {k}");
        assert_eq!(a.bits_down, b.bits_down, "downlink bits at round {k}");
    }
    for wi in 0..n {
        assert_eq!(single.shift(wi), dist.shift(wi), "shift of worker {wi}");
        let snap = dist.worker_snapshot(wi);
        assert_eq!(snap.h, dist.shift(wi), "worker {wi} h vs master replica");
        assert_eq!(
            snap.uplink_error.as_deref(),
            single.uplink_error(wi),
            "worker {wi} EF accumulators diverged"
        );
        assert!(
            single.uplink_error(wi).unwrap().iter().any(|&v| v != 0.0),
            "a K=10% Top-K uplink must leave some residual in worker {wi}"
        );
    }
    // the EF-corrected biased uplink must not blow up
    let x0 = shiftcomp::algorithms::paper_x0(d, 83);
    let err = shiftcomp::linalg::dist_sq(dist.x(), p.x_star())
        / shiftcomp::linalg::dist_sq(&x0, p.x_star());
    assert!(err.is_finite() && err < 1.5, "EF-TopK uplink blew up: rel err {err}");
}

/// f32 wire: the EF packet is pre-quantized by the re-pack, so worker
/// accumulators keep the (f64) quantization residual and both drivers stay
/// bit-identical under f32 precision too.
#[test]
fn ef_uplink_f32_cluster_matches_mirror() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let q = TopK::with_q(d, 0.15);
    let delta = q.delta().unwrap();
    let ss = shiftcomp::theory::ef_uplink(p.as_ref(), &vec![delta; n]);
    let mut single = DcgdShift::dcgd_ef(p.as_ref(), q.clone(), 85);
    single.prec = ValPrec::F32;
    assert_eq!(single.gamma, ss.gamma, "dcgd_ef must take the EF-BV step");
    let mut dist = mk_ef_uplink_cluster(
        &p,
        MethodKind::Fixed,
        ss.gamma,
        &q,
        85,
        ValPrec::F32,
        1,
        None,
    );
    for k in 0..50 {
        let a = single.step(p.as_ref());
        let b = dist.step(p.as_ref());
        assert_eq!(single.x(), dist.x(), "f32 iterates diverged at round {k}");
        assert_eq!(a.bits_up, b.bits_up, "f32 uplink bits at round {k}");
        assert_eq!(a.bits_down, b.bits_down, "f32 downlink bits at round {k}");
    }
    for wi in 0..n {
        let snap = dist.worker_snapshot(wi);
        assert_eq!(
            snap.uplink_error.as_deref(),
            single.uplink_error(wi),
            "f32 accumulators of worker {wi}"
        );
    }
}

/// The worker-side EF invariants, observed through the mirror (where the
/// pending message is computable): the residual obeys the Top-K
/// contraction bound every round, and a forced resync (`set_x0`) flushes
/// every accumulator on both drivers, which stay bit-identical through it.
#[test]
fn ef_uplink_accumulator_invariant_and_resync_flush() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let q = TopK::with_q(d, 0.1);
    let delta = q.delta().unwrap();
    let mut single = DcgdShift::dcgd_ef(p.as_ref(), q.clone(), 87);
    let gamma = single.gamma;
    let mut dist = mk_ef_uplink_cluster(
        &p,
        MethodKind::Fixed,
        gamma,
        &q,
        87,
        ValPrec::F64,
        1,
        None,
    );
    let mut m = vec![0.0; d];
    for k in 0..12 {
        // pending message of worker 0 this round: m = ∇f_0(x) − h_0, and
        // dcgd_ef keeps h ≡ 0
        p.local_grad_into(0, single.x(), &mut m);
        let mut u = single.uplink_error(0).unwrap().to_vec();
        shiftcomp::linalg::axpy(1.0, &m, &mut u);
        let u_sq = shiftcomp::linalg::nrm2_sq(&u);
        single.step(p.as_ref());
        dist.step(p.as_ref());
        assert_eq!(single.x(), dist.x(), "diverged at round {k}");
        let e_sq = shiftcomp::linalg::nrm2_sq(single.uplink_error(0).unwrap());
        let bound = (1.0 - delta) * u_sq;
        assert!(
            e_sq <= bound * (1.0 + 1e-9) + 1e-18,
            "round {k}: residual {e_sq} above contraction bound {bound}"
        );
    }
    assert!(
        single.uplink_error(0).unwrap().iter().any(|&v| v != 0.0),
        "Top-K must have dropped something by now"
    );
    // out-of-band iterate change: the resync must flush every accumulator
    let x_new: Vec<f64> = (0..d).map(|j| 0.3 - 0.02 * j as f64).collect();
    single.set_x0(x_new.clone());
    dist.set_x0(x_new);
    for w in 0..n {
        assert!(
            single.uplink_error(w).unwrap().iter().all(|&v| v == 0.0),
            "mirror worker {w} must flush on set_x0"
        );
    }
    // the cluster's workers flush when the resync frame arrives (next
    // round); bit-identity through the flush pins the equal behaviour
    for k in 0..10 {
        single.step(p.as_ref());
        dist.step(p.as_ref());
        assert_eq!(single.x(), dist.x(), "diverged {k} rounds after set_x0");
    }
    for w in 0..n {
        let snap = dist.worker_snapshot(w);
        assert_eq!(
            snap.uplink_error.as_deref(),
            single.uplink_error(w),
            "worker {w} accumulators after resync"
        );
    }
}

/// Acceptance pin: the Top-K EF uplink ships O(K) payload bits per worker
/// per round — far below the dense d·prec frame a biased-compression-less
/// uplink would need once messages densify (the paper ridge's gradients
/// are fully dense from round 0).
#[test]
fn ef_uplink_bits_up_stay_o_of_k() {
    let p = ridge(); // d = 80, n = 10; dense messages every round
    let d = p.dim();
    let n = p.n_workers();
    let k = 4usize;
    let mut alg = DcgdShift::dcgd_ef(p.as_ref(), TopK::new(d, k), 89);
    let dense_bits_per_worker = d as u64 * 64;
    let mut max_round_bits = 0u64;
    for _ in 0..10 {
        let s = alg.step(p.as_ref());
        assert!(s.bits_up > 0);
        max_round_bits = max_round_bits.max(s.bits_up);
    }
    // K coords at (⌈log₂ 80⌉ + 64) bits + the scale ≈ 348 bits/worker —
    // more than 5× under the dense 5120; pin the margin
    assert!(
        max_round_bits < n as u64 * dense_bits_per_worker / 5,
        "EF-TopK uplink not O(K): {max_round_bits} bits/round"
    );
}

/// Composition: EF uplink × EF downlink × local-step batching. The EF
/// uplink folds once per *sub-step*, the EF downlink once per batch, and
/// the τ-step cluster stays bit-identical to the τ-step mirror — iterate,
/// bits, downlink replicas and both accumulator families.
#[test]
fn ef_uplink_composes_with_ef_downlink_and_local_steps() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let tau = 4usize;
    let mut single = DcgdShift::diana(p.as_ref(), RandK::with_q(d, 0.3), None, 91)
        .with_downlink(Box::new(TopK::with_q(d, 0.25)))
        .with_local_steps(tau)
        .with_uplink_ef();
    let gamma = single.gamma;
    let omega = RandK::with_q(d, 0.3).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let mut dist = mk_ef_uplink_cluster(
        &p,
        MethodKind::Diana {
            alpha: ss.alpha,
            with_c: false,
        },
        gamma,
        &RandK::with_q(d, 0.3),
        91,
        ValPrec::F64,
        tau,
        Some(Box::new(TopK::with_q(d, 0.25))),
    );
    for k in 0..40 {
        let a = single.step(p.as_ref());
        let b = dist.step(p.as_ref());
        assert_eq!(single.x(), dist.x(), "iterates diverged at round {k}");
        assert_eq!(a.bits_up, b.bits_up, "uplink bits at round {k}");
        assert_eq!(a.bits_down, b.bits_down, "downlink bits at round {k}");
        assert_eq!(single.replica(), dist.replica_mirror(), "replicas at round {k}");
        assert_eq!(single.ef_error(), dist.ef_error(), "downlink accumulators at round {k}");
    }
    for wi in 0..n {
        let snap = dist.worker_snapshot(wi);
        assert_eq!(snap.h, dist.shift(wi), "worker {wi} h vs master replica");
        assert_eq!(
            snap.uplink_error.as_deref(),
            single.uplink_error(wi),
            "worker {wi} uplink accumulators"
        );
    }
}

// ------------------------------------- semi-async rounds (quorum gather)

/// Build a cluster with the semi-async knobs spelled out. Everything else
/// follows `mk_ef_uplink_cluster`'s conventions (clone-per-worker `q`,
/// zero shifts, no links).
#[allow(clippy::too_many_arguments)]
fn mk_knobbed_cluster(
    p: &Arc<Ridge>,
    method: MethodKind,
    gamma: f64,
    q: &(impl Compressor + Clone + 'static),
    seed: u64,
    prec: ValPrec,
    local_steps: usize,
    uplink_ef: bool,
    downlink: Option<Box<dyn Compressor>>,
    master_threads: Option<usize>,
    quorum: Option<usize>,
    participation: Option<f64>,
    staleness: bool,
) -> DistributedRunner {
    let d = p.dim();
    let n = p.n_workers();
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
        .collect();
    DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method,
            gamma,
            prec,
            seed,
            local_steps,
            uplink_ef,
            downlink,
            master_threads,
            quorum,
            participation,
            staleness,
            ..Default::default()
        },
    )
}

/// The degenerate pin, across the feature matrix: `quorum = n` plus
/// `participation = 1.0` (and, where legal, `staleness: true` with a
/// quorum that never cuts anyone) is the barrier round bit for bit —
/// the quorum early-close is unreachable when the target equals the
/// fleet, the all-in sampler commands everyone, and the stale lane
/// stays empty. Covered: f64/f32 wire, EF uplink, EF downlink, batched
/// `local_steps`, fold-pool widths 1 and 4.
#[test]
fn semi_async_degenerate_knobs_bit_identical_to_barrier() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();

    let mut check = |mut knobbed: DistributedRunner,
                     mut barrier: DistributedRunner,
                     rounds: usize,
                     label: &str| {
        for k in 0..rounds {
            let a = knobbed.step(p.as_ref());
            let b = barrier.step(p.as_ref());
            assert_eq!(knobbed.x(), barrier.x(), "{label}: iterates diverged at round {k}");
            assert_eq!(a.bits_up, b.bits_up, "{label}: bits_up at round {k}");
            assert_eq!(a.bits_down, b.bits_down, "{label}: bits_down at round {k}");
        }
        assert_eq!(
            knobbed.health().degraded_rounds, 0,
            "{label}: a full quorum with an all-in sampler must never degrade"
        );
    };

    let q = RandK::with_q(d, 0.3);
    let omega = q.omega().unwrap();
    let ss = shiftcomp::theory::dcgd_fixed(p.as_ref(), &vec![omega; n]);

    // f64 wire, serial and 4-way fold pools; staleness armed but starved
    for t in [1usize, 4] {
        check(
            mk_knobbed_cluster(
                &p, MethodKind::Fixed, ss.gamma, &q, 101, ValPrec::F64, 1, false, None,
                Some(t), Some(n), Some(1.0), true,
            ),
            mk_knobbed_cluster(
                &p, MethodKind::Fixed, ss.gamma, &q, 101, ValPrec::F64, 1, false, None,
                Some(t), None, None, false,
            ),
            30,
            &format!("f64 T={t}"),
        );
    }

    // f32 wire precision
    check(
        mk_knobbed_cluster(
            &p, MethodKind::Fixed, ss.gamma, &q, 103, ValPrec::F32, 1, false, None,
            Some(4), Some(n), Some(1.0), true,
        ),
        mk_knobbed_cluster(
            &p, MethodKind::Fixed, ss.gamma, &q, 103, ValPrec::F32, 1, false, None,
            Some(4), None, None, false,
        ),
        30,
        "f32 wire",
    );

    // EF uplink (contractive Top-K fleet)
    let qe = TopK::with_q(d, 0.15);
    let delta = qe.delta().unwrap();
    let ef = shiftcomp::theory::ef_uplink(p.as_ref(), &vec![delta; n]);
    check(
        mk_knobbed_cluster(
            &p, MethodKind::Fixed, ef.gamma, &qe, 105, ValPrec::F64, 1, true, None,
            Some(4), Some(n), Some(1.0), true,
        ),
        mk_knobbed_cluster(
            &p, MethodKind::Fixed, ef.gamma, &qe, 105, ValPrec::F64, 1, true, None,
            Some(4), None, None, false,
        ),
        30,
        "EF uplink",
    );

    // EF downlink (the pooled materialize is on both sides; the knobs
    // must not perturb the shared replica/overlay state machine)
    check(
        mk_knobbed_cluster(
            &p, MethodKind::Fixed, ss.gamma, &q, 107, ValPrec::F64, 1, false,
            Some(Box::new(TopK::with_q(d, 0.25))), Some(4), Some(n), Some(1.0), true,
        ),
        mk_knobbed_cluster(
            &p, MethodKind::Fixed, ss.gamma, &q, 107, ValPrec::F64, 1, false,
            Some(Box::new(TopK::with_q(d, 0.25))), Some(4), None, None, false,
        ),
        30,
        "EF downlink",
    );

    // batched local steps: only `quorum = n` composes with τ > 1 (the
    // sampler and the stale lane are per-round constructions), and it
    // must still be the exact barrier batch round
    check(
        mk_knobbed_cluster(
            &p, MethodKind::Fixed, ss.gamma, &q, 109, ValPrec::F64, 3, false, None,
            Some(4), Some(n), None, false,
        ),
        mk_knobbed_cluster(
            &p, MethodKind::Fixed, ss.gamma, &q, 109, ValPrec::F64, 3, false, None,
            Some(4), None, None, false,
        ),
        30,
        "local_steps=3",
    );
}

/// Partial participation is seeded and mirrored: the cluster's sampler
/// and the single-process driver's draw the same per-round subset from
/// the same stream, so the two trajectories (and their uplink bit
/// accounting — only sampled workers ship frames) are bit-identical.
#[test]
fn participation_cluster_matches_seeded_mirror() {
    let p = ridge();
    let d = p.dim();
    let single = DcgdShift::dcgd(p.as_ref(), RandK::with_q(d, 0.3), 111).with_participation(0.5);
    let gamma = single.gamma;
    let dist = mk_knobbed_cluster(
        &p,
        MethodKind::Fixed,
        gamma,
        &RandK::with_q(d, 0.3),
        111,
        ValPrec::F64,
        1,
        false,
        None,
        None,
        None,
        Some(0.5),
        false,
    );
    assert_trajectories_match(single, dist, p.as_ref(), 60);
}

/// The acceptance scenario as a test: on a heterogeneous fleet where one
/// worker's link is 50× slower than the rest, an m = n/2 quorum close
/// prices rounds at the m-th fastest arrival and must collapse the
/// simulated wall clock ≥ 3× vs the barrier gather — while the iterate
/// stays finite and keeps optimizing (late frames fold in damped).
#[test]
fn quorum_gather_collapses_straggler_wall_clock() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let fast = LinkModel {
        up_bps: 20e6,
        down_bps: 20e6,
        latency: 0.005,
    };
    let slow = LinkModel {
        up_bps: 20e6,
        down_bps: 20e6,
        latency: 0.25,
    };
    let mut links = vec![fast; n];
    links[n - 1] = slow;
    let q = RandK::with_q(d, 0.3);
    let omega = q.omega().unwrap();
    let ss = shiftcomp::theory::dcgd_fixed(p.as_ref(), &vec![omega; n]);
    let mk = |quorum: Option<usize>, staleness: bool| {
        let qs: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
            .collect();
        DistributedRunner::new(
            p.clone(),
            qs,
            None,
            vec![vec![0.0; d]; n],
            ClusterConfig {
                method: MethodKind::Fixed,
                gamma: ss.gamma,
                seed: 113,
                links: Some(links.clone()),
                quorum,
                staleness,
                ..Default::default()
            },
        )
    };
    let rounds = 150usize;
    let mut barrier = mk(None, false);
    for _ in 0..rounds {
        barrier.step(p.as_ref());
    }
    let mut quorum = mk(Some(n / 2), true);
    for _ in 0..rounds {
        quorum.step(p.as_ref());
    }
    let ratio = barrier.simulated_time() / quorum.simulated_time();
    assert!(
        ratio >= 3.0,
        "quorum close must collapse the straggler-bound wall clock ≥ 3×, got {ratio:.2}× \
         ({:.3}s vs {:.3}s)",
        barrier.simulated_time(),
        quorum.simulated_time()
    );
    assert!(quorum.x().iter().all(|v| v.is_finite()));
    // progress, not rate: the damped stale folds must leave the descent
    // intact (the paper ridge converges slowly under the conservative
    // theory step, so pin net descent rather than a rate)
    let x0 = shiftcomp::algorithms::paper_x0(d, 113);
    let denom = shiftcomp::linalg::dist_sq(&x0, p.x_star());
    let err = shiftcomp::linalg::dist_sq(quorum.x(), p.x_star()) / denom;
    assert!(
        err < 1.0,
        "the quorum trajectory must still descend: rel err {err}"
    );
}
