//! The coordinate-sharded parallel fold must be a pure scheduling change:
//! every fold-pool width `T` (via `cluster.master_threads`) produces the
//! **bit-identical** trajectory — iterates, learned shifts, health states,
//! failure records and EF accumulators — as `T = 1`, which is itself the
//! serial fold, pinned against the single-process [`DcgdShift`] mirror.
//!
//! Sharding is by *coordinate*: every `est[j]` / `h[i][j]` / `h_sum[j]`
//! sees exactly the serial worker-order fp op sequence, only the executing
//! thread varies with `j` — so these are equality tests, not tolerance
//! tests.

use std::sync::Arc;

use shiftcomp::algorithms::{Algorithm, DcgdShift};
use shiftcomp::compressors::{Compressor, RandK, TopK, ValPrec};
use shiftcomp::coordinator::{
    ClusterConfig, DistributedRunner, FailureClass, FaultPlan, MethodKind, WorkerState,
};
use shiftcomp::problems::{Problem, Ridge};

/// Generous gather deadline (see `tests/chaos.rs`): only injected faults
/// can hit it on these microsecond-scale rounds.
const TEST_TIMEOUT_MS: u64 = 1_000;

/// The pool widths the issue pins: serial, even split, more shards than
/// the CI runner probably has cores.
const WIDTHS: [usize; 3] = [1, 2, 8];

fn ridge() -> Arc<Ridge> {
    Arc::new(Ridge::paper_default(3))
}

fn boxed_clones(
    q: &(impl Compressor + Clone + 'static),
    n: usize,
) -> Vec<Box<dyn Compressor>> {
    (0..n)
        .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
        .collect()
}

/// Step the mirror and every pooled runner in lockstep, asserting the
/// iterate and uplink-bit agreement each round.
fn assert_widths_match_mirror(
    mut single: DcgdShift,
    runners: &mut [DistributedRunner],
    p: &dyn Problem,
    rounds: usize,
) {
    for k in 0..rounds {
        let ss = single.step(p);
        for dist in runners.iter_mut() {
            let t = dist.fold_threads();
            let sd = dist.step(p);
            assert_eq!(single.x(), dist.x(), "T={t} iterate diverged at round {k}");
            assert_eq!(ss.bits_up, sd.bits_up, "T={t} bits_up at round {k}");
        }
    }
    for wi in 0..p.n_workers() {
        for dist in runners.iter() {
            let t = dist.fold_threads();
            assert_eq!(single.shift(wi), dist.shift(wi), "T={t} shift of worker {wi}");
        }
    }
}

/// DIANA at T ∈ {1, 2, 8}: every pool width reproduces the single-process
/// mirror bit for bit, and the master-time probe is actually armed.
#[test]
fn diana_fold_widths_match_serial_mirror() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let q = RandK::with_q(d, 0.3);
    let single = DcgdShift::diana(p.as_ref(), q.clone(), None, 23);
    let gamma = single.gamma;
    let omega = q.omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let mut runners: Vec<DistributedRunner> = WIDTHS
        .iter()
        .map(|&t| {
            DistributedRunner::new(
                p.clone(),
                boxed_clones(&q, n),
                None,
                vec![vec![0.0; d]; n],
                ClusterConfig {
                    method: MethodKind::Diana {
                        alpha: ss.alpha,
                        with_c: false,
                    },
                    gamma,
                    seed: 23,
                    master_threads: Some(t),
                    ..Default::default()
                },
            )
        })
        .collect();
    assert_eq!(runners[0].fold_threads(), 1);
    assert_eq!(runners[2].fold_threads(), 8);
    assert_widths_match_mirror(single, &mut runners, p.as_ref(), 50);
    for dist in &runners {
        assert!(
            dist.master_seconds() > 0.0,
            "master-time probe must accumulate over 50 rounds"
        );
    }
}

/// Rand-DIANA (refresh C-frames fold into `h_sum`) and DCGD-STAR (shifts
/// recomputed from ∇f_i(x*) inside the fold) across pool widths.
#[test]
fn rand_diana_and_star_fold_widths_match() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();

    let q = RandK::with_q(d, 0.2);
    let single = DcgdShift::rand_diana(p.as_ref(), q.clone(), Some(0.2), 29);
    let gamma = single.gamma;
    let mut runners: Vec<DistributedRunner> = WIDTHS
        .iter()
        .map(|&t| {
            DistributedRunner::new(
                p.clone(),
                boxed_clones(&q, n),
                None,
                vec![vec![0.0; d]; n],
                ClusterConfig {
                    method: MethodKind::RandDiana { p: 0.2 },
                    gamma,
                    seed: 29,
                    master_threads: Some(t),
                    ..Default::default()
                },
            )
        })
        .collect();
    assert_widths_match_mirror(single, &mut runners, p.as_ref(), 60);

    let q = RandK::with_q(d, 0.4);
    let single = DcgdShift::star(p.as_ref(), q.clone(), None, 31);
    let gamma = single.gamma;
    let shifts: Vec<Vec<f64>> = (0..n).map(|i| p.grad_star(i).to_vec()).collect();
    let mut runners: Vec<DistributedRunner> = WIDTHS
        .iter()
        .map(|&t| {
            DistributedRunner::new(
                p.clone(),
                boxed_clones(&q, n),
                None,
                shifts.clone(),
                ClusterConfig {
                    method: MethodKind::Star { with_c: false },
                    gamma,
                    seed: 31,
                    master_threads: Some(t),
                    ..Default::default()
                },
            )
        })
        .collect();
    assert_widths_match_mirror(single, &mut runners, p.as_ref(), 50);
}

/// The full feature matrix from the issue — f32 wire × EF uplink × EF
/// downlink × `local_steps = 4` × a straggler quarantined mid-run — at
/// T ∈ {1, 2, 8}: the batched validate/decode/fold pipeline, the sharded
/// quarantine shift-removal and the rejoin bootstrap must all agree bit
/// for bit across widths (T = 1 being the serial path).
#[test]
fn feature_matrix_fold_widths_bit_identical_through_quarantine() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let tau = 4usize;
    // straggle window covers rounds 6..9; quarantined at the first miss
    let (straggler, from, window) = (2usize, 6usize, 3usize);
    let q = RandK::with_q(d, 0.3);
    let omega = q.omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let mut runners: Vec<DistributedRunner> = WIDTHS
        .iter()
        .map(|&t| {
            DistributedRunner::new(
                p.clone(),
                boxed_clones(&q, n),
                None,
                vec![vec![0.0; d]; n],
                ClusterConfig {
                    method: MethodKind::Diana {
                        alpha: ss.alpha,
                        with_c: false,
                    },
                    gamma: ss.gamma,
                    prec: ValPrec::F32,
                    seed: 37,
                    local_steps: tau,
                    downlink: Some(Box::new(TopK::with_q(d, 0.25))),
                    uplink_ef: true,
                    faults: Some(FaultPlan::new().straggle(straggler, from, window)),
                    round_timeout_ms: TEST_TIMEOUT_MS,
                    quarantine_after: 1,
                    master_threads: Some(t),
                    ..Default::default()
                },
            )
        })
        .collect();

    let check_lockstep = |runners: &mut [DistributedRunner], k: usize| {
        let mut x_ref: Option<Vec<f64>> = None;
        for dist in runners.iter_mut() {
            let t = dist.fold_threads();
            dist.try_step(p.as_ref())
                .unwrap_or_else(|f| panic!("T={t} round {k} must survive the straggle: {f}"));
            match &x_ref {
                None => x_ref = Some(dist.x().to_vec()),
                Some(x) => {
                    assert_eq!(x.as_slice(), dist.x(), "T={t} iterate diverged at round {k}");
                }
            }
        }
    };

    // healthy prefix, then the straggle window (first miss quarantines)
    for k in 0..from + window + 1 {
        check_lockstep(&mut runners, k);
    }
    for dist in &runners {
        let t = dist.fold_threads();
        assert_eq!(
            dist.health().states[straggler],
            WorkerState::Quarantined,
            "T={t} must quarantine the straggler"
        );
        let f = dist.last_failure(straggler).expect("failure recorded");
        assert_eq!(f.class, FailureClass::Timeout, "T={t} failure class");
        assert_eq!(f.round, from, "T={t} quarantine round");
    }

    // readmit on every width and keep checking bit-equality
    for dist in runners.iter_mut() {
        dist.rejoin(straggler).expect("straggler thread is alive");
    }
    for k in 0..8 {
        check_lockstep(&mut runners, from + window + 1 + k);
    }

    // shifts, health and both EF accumulator families across widths
    let (head, tail) = runners.split_first_mut().unwrap();
    assert!(head.health().states.iter().all(|s| *s == WorkerState::Active));
    for dist in tail.iter_mut() {
        let t = dist.fold_threads();
        assert_eq!(head.health().states, dist.health().states, "T={t} health");
        assert_eq!(head.ef_error(), dist.ef_error(), "T={t} downlink EF accumulator");
        for wi in 0..n {
            assert_eq!(head.shift(wi), dist.shift(wi), "T={t} shift of worker {wi}");
            assert_eq!(
                head.worker_snapshot(wi).uplink_error,
                dist.worker_snapshot(wi).uplink_error,
                "T={t} uplink EF accumulator of worker {wi}"
            );
        }
    }
}

/// The parallel downlink build is a pure scheduling change: the pooled
/// delta apply (`x += Δ` sharded by coordinate) and the sharded EF
/// materialize (`x̂[lo..hi] = x_new[lo..hi]` then the overlay residual
/// re-applied per shard) must reproduce the serial build bit for bit at
/// every width — iterate, downlink bit accounting, the broadcast replica
/// and the master's EF accumulator — with the degenerate semi-async
/// knobs armed on top (quorum = n, participation = 1.0).
#[test]
fn pooled_downlink_build_widths_match_mirror() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let q = RandK::with_q(d, 0.3);
    let mut single = DcgdShift::dcgd(p.as_ref(), q.clone(), 43)
        .with_downlink(Box::new(TopK::with_q(d, 0.25)));
    let gamma = single.gamma;
    let mut runners: Vec<DistributedRunner> = WIDTHS
        .iter()
        .map(|&t| {
            DistributedRunner::new(
                p.clone(),
                boxed_clones(&q, n),
                None,
                vec![vec![0.0; d]; n],
                ClusterConfig {
                    method: MethodKind::Fixed,
                    gamma,
                    seed: 43,
                    downlink: Some(Box::new(TopK::with_q(d, 0.25))),
                    master_threads: Some(t),
                    quorum: Some(n),
                    participation: Some(1.0),
                    staleness: true,
                    ..Default::default()
                },
            )
        })
        .collect();
    for k in 0..50 {
        let ss = single.step(p.as_ref());
        for dist in runners.iter_mut() {
            let t = dist.fold_threads();
            let sd = dist.step(p.as_ref());
            assert_eq!(single.x(), dist.x(), "T={t} iterate diverged at round {k}");
            assert_eq!(ss.bits_down, sd.bits_down, "T={t} bits_down at round {k}");
            assert_eq!(
                single.replica(),
                dist.replica_mirror(),
                "T={t} broadcast replica at round {k}"
            );
            assert_eq!(
                single.ef_error(),
                dist.ef_error(),
                "T={t} downlink EF accumulator at round {k}"
            );
        }
    }
}
