//! Property-based tests (proptest_lite) over the framework's invariants.

use shiftcomp::algorithms::{Algorithm, DcgdShift, Gd, RunOpts};
use shiftcomp::compressors::{
    combinators::{scale_packet, Induced, Shifted},
    BernoulliP, Compressor, Identity, NaturalCompression, NaturalDithering, RandK,
    StandardDithering, Ternary, TopK, ZeroCompressor,
};
use shiftcomp::problems::Quadratic;
use shiftcomp::util::proptest_lite::{check_close, run, Gen};
use shiftcomp::util::rng::Pcg64;
use shiftcomp::wire;

fn random_unbiased(g: &mut Gen, d: usize) -> Box<dyn Compressor> {
    match g.usize_in(0, 5) {
        0 => Box::new(Identity::new(d)),
        1 => Box::new(RandK::new(d, g.usize_in(1, d))),
        2 => Box::new(NaturalDithering::l2(d, g.usize_in(1, 10) as u8)),
        3 => Box::new(NaturalCompression::new(d)),
        4 => Box::new(BernoulliP::new(d, g.f64_in(0.05, 1.0))),
        _ => Box::new(Ternary::new(d)),
    }
}

fn random_biased(g: &mut Gen, d: usize) -> Box<dyn Compressor> {
    match g.usize_in(0, 2) {
        0 => Box::new(TopK::new(d, g.usize_in(1, d))),
        1 => Box::new(ZeroCompressor::new(d)),
        _ => Box::new(shiftcomp::compressors::SignScaled::new(d)),
    }
}

/// Every unbiased compressor: Monte-Carlo mean ≈ x and empirical variance
/// within the advertised ω (with CI slack).
#[test]
fn prop_unbiased_contract() {
    run(20, 0xB1A5, |g| {
        let d = g.usize_in(2, 60);
        let c = random_unbiased(g, d);
        let x = g.vec_normal(d, 2.0);
        if x.iter().all(|&v| v == 0.0) {
            return Ok(());
        }
        let mut rng = Pcg64::new(g.rng.next_u64());
        let bias = shiftcomp::compressors::empirical_bias_ratio(c.as_ref(), &mut rng, &x, 4_000);
        if bias > 0.2 {
            return Err(format!("{}: bias ratio {bias}", c.name()));
        }
        let ratio =
            shiftcomp::compressors::empirical_variance_ratio(c.as_ref(), &mut rng, &x, 1_500);
        let omega = c.omega().unwrap();
        if ratio > omega * 1.35 + 0.1 {
            return Err(format!("{}: variance {ratio} > ω {omega}", c.name()));
        }
        Ok(())
    });
}

/// Every contractive compressor: ‖C(x) − x‖² ≤ (1 − δ)‖x‖² empirically.
#[test]
fn prop_contractive_contract() {
    run(30, 0xB1A6, |g| {
        let d = g.usize_in(2, 60);
        let c = random_biased(g, d);
        let x = g.vec_mixed_scale(d);
        let n2 = shiftcomp::linalg::nrm2_sq(&x);
        if n2 == 0.0 {
            return Ok(());
        }
        let mut rng = Pcg64::new(g.rng.next_u64());
        let mut acc: f64 = 0.0;
        let trials = 300;
        for _ in 0..trials {
            let out = c.compress(&mut rng, &x).decode();
            acc += shiftcomp::linalg::dist_sq(&out, &x);
        }
        let ratio = acc / trials as f64 / n2;
        let delta = c.delta().unwrap();
        if ratio > (1.0 - delta) * 1.05 + 1e-12 {
            return Err(format!("{}: {ratio} > 1 − δ = {}", c.name(), 1.0 - delta));
        }
        Ok(())
    });
}

/// Wire: encode ∘ decode = identity for every packet any compressor emits.
#[test]
fn prop_wire_roundtrip_all_compressors() {
    run(60, 0x3172, |g| {
        let d = g.usize_in(1, 100);
        let c: Box<dyn Compressor> = if g.bool() {
            random_unbiased(g, d)
        } else {
            random_biased(g, d)
        };
        let x = g.vec_mixed_scale(d);
        let mut rng = Pcg64::new(g.rng.next_u64());
        let pkt = c.compress(&mut rng, &x);
        let bytes = wire::encode(&pkt, shiftcomp::compressors::ValPrec::F64);
        let back = wire::decode(&bytes).map_err(|e| format!("{}: {e}", c.name()))?;
        if back != pkt {
            return Err(format!("{}: packet mutated on the wire", c.name()));
        }
        Ok(())
    });
}

/// Lemma 1 (shift composition): v + Q_h(x − v) has zero variance at
/// x = h + v and is unbiased everywhere.
#[test]
fn prop_lemma1_shift_composition() {
    run(20, 0x1e44a1, |g| {
        let d = g.usize_in(2, 40);
        let h = g.vec_normal(d, 1.5);
        let v = g.vec_normal(d, 1.5);
        let q = Shifted::new(h.clone(), random_unbiased(g, d));
        let mut rng = Pcg64::new(g.rng.next_u64());
        // zero variance at the composed shift point
        let hv: Vec<f64> = h.iter().zip(v.iter()).map(|(a, b)| a + b).collect();
        let arg: Vec<f64> = hv.iter().zip(v.iter()).map(|(a, b)| a - b).collect();
        let mut out = q.apply(&mut rng, &arg);
        for j in 0..d {
            out[j] += v[j];
        }
        check_close(&out, &hv, 1e-9, 1e-9, "Q(h+v) must equal h+v exactly")
    });
}

/// Lemma 3 (induced compressor): empirical variance ≤ ω(1−δ) and mean ≈ x.
#[test]
fn prop_induced_omega() {
    run(15, 0x17d, |g| {
        let d = g.usize_in(4, 40);
        let ind = Induced::new(random_biased(g, d), random_unbiased(g, d));
        let omega = match ind.omega() {
            Some(w) => w,
            None => return Ok(()),
        };
        let x = g.vec_normal(d, 2.0);
        let n2 = shiftcomp::linalg::nrm2_sq(&x);
        if n2 == 0.0 {
            return Ok(());
        }
        let mut rng = Pcg64::new(g.rng.next_u64());
        let trials = 1_500;
        let mut acc = 0.0;
        for _ in 0..trials {
            let out = ind.apply(&mut rng, &x).decode();
            acc += shiftcomp::linalg::dist_sq(&out, &x);
        }
        let ratio = acc / trials as f64 / n2;
        if ratio > omega * 1.35 + 0.1 {
            return Err(format!(
                "induced({}, {}): {ratio} > ω(1−δ) = {omega}",
                ind.c.name(),
                ind.q.name()
            ));
        }
        Ok(())
    });
}

/// scale_packet(pkt, a).decode() == a * pkt.decode() for random packets.
#[test]
fn prop_scale_packet_linearity() {
    run(40, 0x5ca1e, |g| {
        let d = g.usize_in(1, 50);
        let c: Box<dyn Compressor> = if g.bool() {
            random_unbiased(g, d)
        } else {
            random_biased(g, d)
        };
        let x = g.vec_normal(d, 1.0);
        let a = g.f64_in(-3.0, 3.0);
        let mut r1 = Pcg64::new(77);
        let mut r2 = Pcg64::new(77);
        let plain = c.compress(&mut r1, &x).decode();
        let scaled = scale_packet(c.compress(&mut r2, &x), a).decode();
        let expect: Vec<f64> = plain.iter().map(|v| v * a).collect();
        check_close(&scaled, &expect, 1e-10, 1e-10, &c.name())
    });
}

/// Algorithmic reduction: DCGD-SHIFT with the Identity compressor follows
/// exact distributed GD for any quadratic and any seed.
#[test]
fn prop_identity_reduces_to_gd() {
    run(8, 0x6d, |g| {
        let d = g.usize_in(3, 15);
        let n = g.usize_in(2, 5);
        let seed = g.rng.next_u64();
        let p = Quadratic::random(d, n, 0.5, 8.0, seed);
        let mut alg = DcgdShift::dcgd(&p, Identity::new(d), seed);
        let gamma = alg.gamma;
        let mut gd = Gd::with_gamma(&p, gamma, seed);
        for _ in 0..40 {
            alg.step(&p);
            gd.step(&p);
        }
        check_close(alg.x(), gd.x(), 1e-9, 1e-9, "identity ≠ GD")
    });
}

/// StandardDithering also respects its QSGD ω bound.
#[test]
fn prop_standard_dithering_bound() {
    run(12, 0x5d, |g| {
        let d = g.usize_in(4, 80);
        let s = g.usize_in(1, 16) as u32;
        let c = StandardDithering::new(d, s);
        let x = g.vec_normal(d, 3.0);
        let mut rng = Pcg64::new(g.rng.next_u64());
        let ratio = shiftcomp::compressors::empirical_variance_ratio(&c, &mut rng, &x, 1_500);
        let omega = c.omega().unwrap();
        if ratio > omega * 1.35 + 0.05 {
            return Err(format!("std-dith(s={s}, d={d}): {ratio} > {omega}"));
        }
        Ok(())
    });
}

/// Determinism: the full stack is reproducible from the seed.
#[test]
fn prop_full_run_deterministic() {
    run(4, 0xde7e44, |g| {
        let seed = g.rng.next_u64();
        let p = Quadratic::random(10, 3, 1.0, 10.0, seed);
        let mk = || {
            let mut alg = DcgdShift::rand_diana(&p, RandK::new(10, 3), None, seed);
            let t = alg.run(
                &p,
                &RunOpts {
                    max_rounds: 200,
                    tol: 0.0,
                    record_every: 10,
                    ..Default::default()
                },
            );
            (alg.x().to_vec(), t.total_bits_up())
        };
        let (x1, b1) = mk();
        let (x2, b2) = mk();
        if x1 != x2 || b1 != b2 {
            return Err("same seed produced different runs".into());
        }
        Ok(())
    });
}
