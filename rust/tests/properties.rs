//! Property-based tests (proptest_lite) over the framework's invariants.

use shiftcomp::algorithms::{Algorithm, DcgdShift, Gd, RunOpts};
use shiftcomp::compressors::{
    combinators::{scale_packet, Induced, Shifted},
    BernoulliP, Compressor, Identity, NaturalCompression, NaturalDithering, RandK,
    StandardDithering, Ternary, TopK, ZeroCompressor,
};
use shiftcomp::problems::Quadratic;
use shiftcomp::util::proptest_lite::{check_close, run, Gen};
use shiftcomp::util::rng::Pcg64;
use shiftcomp::wire;

fn random_unbiased(g: &mut Gen, d: usize) -> Box<dyn Compressor> {
    match g.usize_in(0, 5) {
        0 => Box::new(Identity::new(d)),
        1 => Box::new(RandK::new(d, g.usize_in(1, d))),
        2 => Box::new(NaturalDithering::l2(d, g.usize_in(1, 10) as u8)),
        3 => Box::new(NaturalCompression::new(d)),
        4 => Box::new(BernoulliP::new(d, g.f64_in(0.05, 1.0))),
        _ => Box::new(Ternary::new(d)),
    }
}

fn random_biased(g: &mut Gen, d: usize) -> Box<dyn Compressor> {
    match g.usize_in(0, 2) {
        0 => Box::new(TopK::new(d, g.usize_in(1, d))),
        1 => Box::new(ZeroCompressor::new(d)),
        _ => Box::new(shiftcomp::compressors::SignScaled::new(d)),
    }
}

/// Every unbiased compressor: Monte-Carlo mean ≈ x and empirical variance
/// within the advertised ω (with CI slack).
#[test]
fn prop_unbiased_contract() {
    run(20, 0xB1A5, |g| {
        let d = g.usize_in(2, 60);
        let c = random_unbiased(g, d);
        let x = g.vec_normal(d, 2.0);
        if x.iter().all(|&v| v == 0.0) {
            return Ok(());
        }
        let mut rng = Pcg64::new(g.rng.next_u64());
        let bias = shiftcomp::compressors::empirical_bias_ratio(c.as_ref(), &mut rng, &x, 4_000);
        if bias > 0.2 {
            return Err(format!("{}: bias ratio {bias}", c.name()));
        }
        let ratio =
            shiftcomp::compressors::empirical_variance_ratio(c.as_ref(), &mut rng, &x, 1_500);
        let omega = c.omega().unwrap();
        if ratio > omega * 1.35 + 0.1 {
            return Err(format!("{}: variance {ratio} > ω {omega}", c.name()));
        }
        Ok(())
    });
}

/// Every contractive compressor: ‖C(x) − x‖² ≤ (1 − δ)‖x‖² empirically.
#[test]
fn prop_contractive_contract() {
    run(30, 0xB1A6, |g| {
        let d = g.usize_in(2, 60);
        let c = random_biased(g, d);
        let x = g.vec_mixed_scale(d);
        let n2 = shiftcomp::linalg::nrm2_sq(&x);
        if n2 == 0.0 {
            return Ok(());
        }
        let mut rng = Pcg64::new(g.rng.next_u64());
        let mut acc: f64 = 0.0;
        let trials = 300;
        for _ in 0..trials {
            let out = c.compress(&mut rng, &x).decode();
            acc += shiftcomp::linalg::dist_sq(&out, &x);
        }
        let ratio = acc / trials as f64 / n2;
        let delta = c.delta().unwrap();
        if ratio > (1.0 - delta) * 1.05 + 1e-12 {
            return Err(format!("{}: {ratio} > 1 − δ = {}", c.name(), 1.0 - delta));
        }
        Ok(())
    });
}

/// Wire: encode ∘ decode = identity for every packet any compressor emits.
#[test]
fn prop_wire_roundtrip_all_compressors() {
    run(60, 0x3172, |g| {
        let d = g.usize_in(1, 100);
        let c: Box<dyn Compressor> = if g.bool() {
            random_unbiased(g, d)
        } else {
            random_biased(g, d)
        };
        let x = g.vec_mixed_scale(d);
        let mut rng = Pcg64::new(g.rng.next_u64());
        let pkt = c.compress(&mut rng, &x);
        let bytes = wire::encode(&pkt, shiftcomp::compressors::ValPrec::F64);
        let back = wire::decode(&bytes).map_err(|e| format!("{}: {e}", c.name()))?;
        if back != pkt {
            return Err(format!("{}: packet mutated on the wire", c.name()));
        }
        Ok(())
    });
}

/// `Packet::quantize` is exactly "what the f32 wire does": a quantized
/// packet equals its own encode → decode round-trip at f32, the raw
/// packet's f32 round-trip lands on the quantized packet, and the encoded
/// bytes of raw and quantized packets are identical — the invariant the
/// shift-replica symmetry fix relies on (workers apply the quantized
/// packet, the master applies the decoded frame; they must agree).
#[test]
fn prop_quantize_equals_f32_wire_roundtrip() {
    use shiftcomp::compressors::ValPrec;
    run(60, 0x94a17, |g| {
        let d = g.usize_in(1, 100);
        let c: Box<dyn Compressor> = if g.bool() {
            random_unbiased(g, d)
        } else {
            random_biased(g, d)
        };
        let x = g.vec_mixed_scale(d);
        let mut rng = Pcg64::new(g.rng.next_u64());
        let pkt = c.compress(&mut rng, &x);
        let mut quantized = pkt.clone();
        quantized.quantize(ValPrec::F32);
        let raw_bytes = wire::encode(&pkt, ValPrec::F32);
        let q_bytes = wire::encode(&quantized, ValPrec::F32);
        if raw_bytes != q_bytes {
            return Err(format!("{}: quantize changed the wire bytes", c.name()));
        }
        let back = wire::decode(&raw_bytes).map_err(|e| format!("{}: {e}", c.name()))?;
        if back != quantized {
            return Err(format!(
                "{}: f32 wire round-trip != quantize ({back:?} vs {quantized:?})",
                c.name()
            ));
        }
        // idempotence: a second round-trip is the identity
        let again = wire::decode(&wire::encode(&back, ValPrec::F32))
            .map_err(|e| format!("{}: {e}", c.name()))?;
        if again != back {
            return Err(format!("{}: f32 round-trip not idempotent", c.name()));
        }
        Ok(())
    });
}

/// Lemma 1 (shift composition): v + Q_h(x − v) has zero variance at
/// x = h + v and is unbiased everywhere.
#[test]
fn prop_lemma1_shift_composition() {
    run(20, 0x1e44a1, |g| {
        let d = g.usize_in(2, 40);
        let h = g.vec_normal(d, 1.5);
        let v = g.vec_normal(d, 1.5);
        let q = Shifted::new(h.clone(), random_unbiased(g, d));
        let mut rng = Pcg64::new(g.rng.next_u64());
        // zero variance at the composed shift point
        let hv: Vec<f64> = h.iter().zip(v.iter()).map(|(a, b)| a + b).collect();
        let arg: Vec<f64> = hv.iter().zip(v.iter()).map(|(a, b)| a - b).collect();
        let mut out = q.apply(&mut rng, &arg);
        for j in 0..d {
            out[j] += v[j];
        }
        check_close(&out, &hv, 1e-9, 1e-9, "Q(h+v) must equal h+v exactly")
    });
}

/// Lemma 3 (induced compressor): empirical variance ≤ ω(1−δ) and mean ≈ x.
#[test]
fn prop_induced_omega() {
    run(15, 0x17d, |g| {
        let d = g.usize_in(4, 40);
        let ind = Induced::new(random_biased(g, d), random_unbiased(g, d));
        let omega = match ind.omega() {
            Some(w) => w,
            None => return Ok(()),
        };
        let x = g.vec_normal(d, 2.0);
        let n2 = shiftcomp::linalg::nrm2_sq(&x);
        if n2 == 0.0 {
            return Ok(());
        }
        let mut rng = Pcg64::new(g.rng.next_u64());
        let trials = 1_500;
        let mut acc = 0.0;
        for _ in 0..trials {
            let out = ind.apply(&mut rng, &x).decode();
            acc += shiftcomp::linalg::dist_sq(&out, &x);
        }
        let ratio = acc / trials as f64 / n2;
        if ratio > omega * 1.35 + 0.1 {
            return Err(format!(
                "induced({}, {}): {ratio} > ω(1−δ) = {omega}",
                ind.c.name(),
                ind.q.name()
            ));
        }
        Ok(())
    });
}

/// scale_packet(pkt, a).decode() == a * pkt.decode() for random packets.
#[test]
fn prop_scale_packet_linearity() {
    run(40, 0x5ca1e, |g| {
        let d = g.usize_in(1, 50);
        let c: Box<dyn Compressor> = if g.bool() {
            random_unbiased(g, d)
        } else {
            random_biased(g, d)
        };
        let x = g.vec_normal(d, 1.0);
        let a = g.f64_in(-3.0, 3.0);
        let mut r1 = Pcg64::new(77);
        let mut r2 = Pcg64::new(77);
        let plain = c.compress(&mut r1, &x).decode();
        let scaled = scale_packet(c.compress(&mut r2, &x), a).decode();
        let expect: Vec<f64> = plain.iter().map(|v| v * a).collect();
        check_close(&scaled, &expect, 1e-10, 1e-10, &c.name())
    });
}

/// Algorithmic reduction: DCGD-SHIFT with the Identity compressor follows
/// exact distributed GD for any quadratic and any seed.
#[test]
fn prop_identity_reduces_to_gd() {
    run(8, 0x6d, |g| {
        let d = g.usize_in(3, 15);
        let n = g.usize_in(2, 5);
        let seed = g.rng.next_u64();
        let p = Quadratic::random(d, n, 0.5, 8.0, seed);
        let mut alg = DcgdShift::dcgd(&p, Identity::new(d), seed);
        let gamma = alg.gamma;
        let mut gd = Gd::with_gamma(&p, gamma, seed);
        for _ in 0..40 {
            alg.step(&p);
            gd.step(&p);
        }
        check_close(alg.x(), gd.x(), 1e-9, 1e-9, "identity ≠ GD")
    });
}

/// StandardDithering also respects its QSGD ω bound.
#[test]
fn prop_standard_dithering_bound() {
    run(12, 0x5d, |g| {
        let d = g.usize_in(4, 80);
        let s = g.usize_in(1, 16) as u32;
        let c = StandardDithering::new(d, s);
        let x = g.vec_normal(d, 3.0);
        let mut rng = Pcg64::new(g.rng.next_u64());
        let ratio = shiftcomp::compressors::empirical_variance_ratio(&c, &mut rng, &x, 1_500);
        let omega = c.omega().unwrap();
        if ratio > omega * 1.35 + 0.05 {
            return Err(format!("std-dith(s={s}, d={d}): {ratio} > {omega}"));
        }
        Ok(())
    });
}

/// `add_scaled_into` is bit-identical to dense `decode` + axpy for every
/// packet variant any compressor emits (the sparse-aware aggregation path
/// must not perturb trajectories).
#[test]
fn prop_add_scaled_into_matches_decode_axpy() {
    run(60, 0xadd5, |g| {
        let d = g.usize_in(1, 80);
        let c: Box<dyn Compressor> = if g.bool() {
            random_unbiased(g, d)
        } else {
            random_biased(g, d)
        };
        let x = g.vec_mixed_scale(d);
        let alpha = g.f64_in(-3.0, 3.0);
        let mut rng = Pcg64::new(g.rng.next_u64());
        let pkt = c.compress(&mut rng, &x);
        // accumulator with no ±0.0 entries (the sparse path skips explicit
        // zeros, which would otherwise normalize -0.0 to +0.0)
        let acc: Vec<f64> = (0..d).map(|_| g.f64_in(0.5, 2.0)).collect();
        let mut want = acc.clone();
        let dec = pkt.decode();
        for j in 0..d {
            want[j] += alpha * dec[j];
        }
        let mut got = acc;
        pkt.add_scaled_into(alpha, &mut got);
        for j in 0..d {
            if got[j].to_bits() != want[j].to_bits() {
                return Err(format!(
                    "{}: coord {j}: {} vs {} (alpha {alpha})",
                    c.name(),
                    got[j],
                    want[j]
                ));
            }
        }
        Ok(())
    });
}

/// `compress_into` produces the identical packet — and consumes the RNG
/// identically — to `compress`, regardless of what the reused scratch
/// packet previously held.
#[test]
fn prop_compress_into_matches_compress() {
    run(60, 0xc0137, |g| {
        let d = g.usize_in(1, 80);
        let c: Box<dyn Compressor> = if g.bool() {
            random_unbiased(g, d)
        } else {
            random_biased(g, d)
        };
        let seed = g.rng.next_u64();
        // dirty scratch from a different random compressor (often a
        // mismatched variant, exercising the replace path)
        let other: Box<dyn Compressor> = if g.bool() {
            random_unbiased(g, d)
        } else {
            random_biased(g, d)
        };
        let mut scratch = other.compress(&mut Pcg64::new(seed ^ 1), &g.vec_normal(d, 1.0));

        for trial in 0..2 {
            // trial 1 reuses the now variant-matched scratch
            let x = g.vec_mixed_scale(d);
            let mut r1 = Pcg64::new(seed.wrapping_add(trial));
            let mut r2 = r1.clone();
            let fresh = c.compress(&mut r1, &x);
            c.compress_into(&mut r2, &x, &mut scratch);
            if fresh != scratch {
                return Err(format!("{}: packet mismatch (trial {trial})", c.name()));
            }
            if r1.next_u64() != r2.next_u64() {
                return Err(format!("{}: RNG streams diverged (trial {trial})", c.name()));
            }
        }
        Ok(())
    });
}

/// Wire reuse paths: `encode_into` is byte-identical to `encode` and
/// `decode_into` reproduces `decode` into dirty recycled packets.
#[test]
fn prop_wire_into_paths_match() {
    run(60, 0x317e2, |g| {
        let d = g.usize_in(1, 100);
        let c: Box<dyn Compressor> = if g.bool() {
            random_unbiased(g, d)
        } else {
            random_biased(g, d)
        };
        let x = g.vec_mixed_scale(d);
        let mut rng = Pcg64::new(g.rng.next_u64());
        let pkt = c.compress(&mut rng, &x);
        let prec = shiftcomp::compressors::ValPrec::F64;
        let fresh = wire::encode(&pkt, prec);
        let mut buf = vec![0xA5u8; g.usize_in(0, 32)];
        wire::encode_into(&pkt, prec, &mut buf);
        if fresh != buf {
            return Err(format!("{}: encode_into bytes differ", c.name()));
        }
        // decode into a dirty scratch packet of some other shape
        let other: Box<dyn Compressor> = random_unbiased(g, d);
        let mut scratch = other.compress(&mut Pcg64::new(7), &x);
        wire::decode_into(&buf, &mut scratch).map_err(|e| format!("{}: {e}", c.name()))?;
        if scratch != pkt {
            return Err(format!("{}: decode_into packet differs", c.name()));
        }
        Ok(())
    });
}

/// Determinism: the full stack is reproducible from the seed.
#[test]
fn prop_full_run_deterministic() {
    run(4, 0xde7e44, |g| {
        let seed = g.rng.next_u64();
        let p = Quadratic::random(10, 3, 1.0, 10.0, seed);
        let mk = || {
            let mut alg = DcgdShift::rand_diana(&p, RandK::new(10, 3), None, seed);
            let t = alg.run(
                &p,
                &RunOpts {
                    max_rounds: 200,
                    tol: 0.0,
                    record_every: 10,
                    ..Default::default()
                },
            );
            (alg.x().to_vec(), t.total_bits_up())
        };
        let (x1, b1) = mk();
        let (x2, b2) = mk();
        if x1 != x2 || b1 != b2 {
            return Err("same seed produced different runs".into());
        }
        Ok(())
    });
}

/// The downlink/refresh delta builder: applying the packet it returns is
/// bit-identical to the dense `axpy(scale, v, out)` reference on every
/// coordinate `v` carries, regardless of which representation it picked —
/// the invariant that keeps delta-broadcast trajectories equal to the
/// dense broadcast.
#[test]
fn prop_update_packet_matches_dense_axpy() {
    use shiftcomp::compressors::ValPrec;
    run(80, 0xde17a, |g| {
        let d = g.usize_in(1, 120);
        // mixed density: some runs near-empty, some fully dense
        let keep = g.f64_in(0.0, 1.0);
        let v: Vec<f64> = (0..d)
            .map(|_| {
                if g.f64_in(0.0, 1.0) < keep {
                    g.f64_in(-5.0, 5.0)
                } else {
                    0.0
                }
            })
            .collect();
        let scale = g.f64_in(-3.0, 3.0);
        let mut scratch = wire::DeltaScratch::with_capacity(0);
        let pkt = wire::build_update_packet(&v, scale, ValPrec::F64, &mut scratch);
        let acc: Vec<f64> = (0..d).map(|_| g.f64_in(0.5, 2.0)).collect();
        let mut want = acc.clone();
        shiftcomp::linalg::axpy(scale, &v, &mut want);
        let mut got = acc.clone();
        pkt.add_scaled_into(1.0, &mut got);
        for j in 0..d {
            if v[j] != 0.0 && got[j].to_bits() != want[j].to_bits() {
                return Err(format!("coord {j}: {} vs {} (scale {scale})", got[j], want[j]));
            }
            if v[j] == 0.0 && got[j] != want[j] {
                return Err(format!("untouched coord {j} changed: {} vs {}", got[j], want[j]));
            }
        }
        // the packet must survive the wire round-trip bit-exactly (both
        // precisions — values are pre-quantized)
        for prec in [ValPrec::F64, ValPrec::F32] {
            let pkt = wire::build_update_packet(&v, scale, prec, &mut scratch);
            let mut buf = Vec::new();
            wire::encode_down_into(wire::DownKind::Delta, pkt, prec, &mut buf);
            let mut back = shiftcomp::compressors::Packet::Zero { dim: 0 };
            wire::decode_down_into(&buf, &mut back).map_err(|e| e.to_string())?;
            if &back != pkt {
                return Err(format!("{prec:?} wire round-trip not lossless"));
            }
        }
        Ok(())
    });
}

/// The payload-bits cache returns exactly what the direct formula returns
/// for every packet any compressor emits, across shape changes.
#[test]
fn prop_payload_bits_cache_exact() {
    use shiftcomp::compressors::PayloadBitsCache;
    run(60, 0xcac4e, |g| {
        let mut cache = PayloadBitsCache::new();
        for _ in 0..4 {
            let d = g.usize_in(1, 90);
            let c: Box<dyn Compressor> = if g.bool() {
                random_unbiased(g, d)
            } else {
                random_biased(g, d)
            };
            let x = g.vec_mixed_scale(d);
            let mut rng = Pcg64::new(g.rng.next_u64());
            let pkt = c.compress(&mut rng, &x);
            for prec in [
                shiftcomp::compressors::ValPrec::F64,
                shiftcomp::compressors::ValPrec::F32,
            ] {
                if cache.bits(&pkt, prec) != pkt.payload_bits(prec) {
                    return Err(format!("{}: cache mismatch", c.name()));
                }
            }
        }
        Ok(())
    });
}

// ------------------------------------------------- network straggler props

fn random_link(g: &mut Gen) -> shiftcomp::net::LinkModel {
    shiftcomp::net::LinkModel {
        up_bps: g.f64_in(1e3, 1e9),
        down_bps: g.f64_in(1e3, 1e9),
        latency: g.f64_in(0.0, 0.5),
    }
}

/// The round cost equals the slowest worker's uplink + broadcast, exactly,
/// under arbitrary heterogeneous fleets — no other worker can stretch (or
/// shrink) the round.
#[test]
fn prop_round_cost_is_the_straggler() {
    use shiftcomp::net::NetworkAccountant;
    run(300, 0x57A6, |g| {
        let n = g.usize_in(1, 12);
        let links: Vec<_> = (0..n).map(|_| random_link(g)).collect();
        let up_bits: Vec<u64> = (0..n).map(|_| g.usize_in(0, 1 << 20) as u64).collect();
        let down_bits = g.usize_in(0, 1 << 20) as u64;
        let mut acc = NetworkAccountant::new(links.clone());
        let t = acc.round(&up_bits, down_bits);
        let reference = up_bits
            .iter()
            .zip(links.iter())
            .map(|(b, l)| l.uplink_time(*b) + l.downlink_time(down_bits))
            .fold(0.0f64, f64::max);
        if t != reference {
            return Err(format!("round {t} != straggler reference {reference}"));
        }
        if acc.sim_time != t || acc.rounds != 1 {
            return Err("accumulation mismatch".into());
        }
        Ok(())
    });
}

/// Staged and pipelined pricing are honest under heterogeneous fleets and
/// per-worker compute: the staged round equals the slowest worker's
/// `down + compute + up` exactly; the pipelined round is never below any
/// stage cost (the comm-only round, any worker's compute), never above
/// the staged no-overlap cost, and exactly the staged cost when there is
/// a single stage to overlap.
#[test]
fn prop_pipelined_bounded_by_stages() {
    use shiftcomp::net::NetworkAccountant;
    run(300, 0x9173, |g| {
        let n = g.usize_in(1, 10);
        let links: Vec<_> = (0..n).map(|_| random_link(g)).collect();
        let up_bits: Vec<u64> = (0..n).map(|_| g.usize_in(0, 1 << 22) as u64).collect();
        let down_bits = g.usize_in(0, 1 << 22) as u64;
        let compute: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 2.0)).collect();
        let stages = g.usize_in(1, 32);
        let comm = NetworkAccountant::new(links.clone()).round(&up_bits, down_bits);
        let staged =
            NetworkAccountant::new(links.clone()).round_staged(&up_bits, down_bits, &compute);
        let piped = NetworkAccountant::new(links.clone()).round_pipelined(
            &up_bits, down_bits, &compute, stages,
        );
        // the staged straggler, recomputed per worker with its own compute
        let reference = (0..n)
            .map(|i| {
                links[i].downlink_time(down_bits) + compute[i] + links[i].uplink_time(up_bits[i])
            })
            .fold(0.0f64, f64::max);
        if staged != reference {
            return Err(format!("staged {staged} != per-worker reference {reference}"));
        }
        let max_compute = compute.iter().fold(0.0f64, |a, &b| a.max(b));
        let tol = 1e-12 * staged.abs().max(1.0);
        if piped < comm.max(max_compute) - tol {
            return Err(format!(
                "pipelined {piped} below stage max {} (comm {comm}, max compute {max_compute})",
                comm.max(max_compute)
            ));
        }
        if piped > staged + tol {
            return Err(format!("pipelined {piped} above staged {staged}"));
        }
        let one_stage = NetworkAccountant::new(links.clone()).round_pipelined(
            &up_bits, down_bits, &compute, 1,
        );
        if (one_stage - staged).abs() > tol {
            return Err(format!(
                "one-stage pipeline {one_stage} must equal staged {staged}"
            ));
        }
        Ok(())
    });
}
