//! Counting-allocator verification of the zero-allocation round contract
//! (see `compressors::packet` and `coordinator::runner` module docs).
//!
//! The allocator counts per-thread: worker threads own their (recycled)
//! buffers, so only the calling thread's allocations are asserted. The
//! whole file is one test binary so no unrelated test threads run
//! concurrently with the armed allocator.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use shiftcomp::algorithms::{Algorithm, DcgdShift, Gdci};
use shiftcomp::compressors::{Compressor, RandK, ValPrec};
use shiftcomp::coordinator::{
    ClusterConfig, DistributedRunner, FaultPlan, MethodKind, WorkerState,
};
use shiftcomp::problems::Problem;

// ------------------------------------------------------ counting allocator

struct CountingAlloc;

static ARMED: AtomicBool = AtomicBool::new(false);
thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

#[inline]
fn bump() {
    if ARMED.load(Ordering::Relaxed) {
        // try_with: never panic during TLS teardown
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Serializes the tests in this binary: `ARMED` is global, so concurrent
/// arm/disarm windows could otherwise truncate each other's measurement.
static TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Count heap allocations made by `f` **on this thread**.
fn thread_allocs<F: FnOnce()>(f: F) -> u64 {
    ALLOCS.with(|c| c.set(0));
    ARMED.store(true, Ordering::SeqCst);
    f();
    ARMED.store(false, Ordering::SeqCst);
    ALLOCS.with(|c| c.get())
}

// ------------------------------------------------- allocation-free problem

/// Gradient = x − target per worker; `local_grad_into` touches no heap.
struct MeanProblem {
    d: usize,
    n: usize,
    targets: Vec<Vec<f64>>,
    x_star: Vec<f64>,
    grad_star: Vec<Vec<f64>>,
}

impl MeanProblem {
    fn new(d: usize, n: usize, seed: u64) -> Self {
        let mut rng = shiftcomp::util::rng::Pcg64::new(seed);
        let targets: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..d).map(|_| rng.normal()).collect())
            .collect();
        let mut x_star = vec![0.0; d];
        for t in &targets {
            shiftcomp::linalg::axpy(1.0 / n as f64, t, &mut x_star);
        }
        let grad_star = targets
            .iter()
            .map(|t| x_star.iter().zip(t).map(|(x, t)| x - t).collect())
            .collect();
        Self {
            d,
            n,
            targets,
            x_star,
            grad_star,
        }
    }
}

impl Problem for MeanProblem {
    fn dim(&self) -> usize {
        self.d
    }
    fn n_workers(&self) -> usize {
        self.n
    }
    fn local_grad_into(&self, worker: usize, x: &[f64], out: &mut [f64]) {
        for j in 0..self.d {
            out[j] = x[j] - self.targets[worker][j];
        }
    }
    fn local_loss(&self, worker: usize, x: &[f64]) -> f64 {
        0.5 * shiftcomp::linalg::dist_sq(x, &self.targets[worker])
    }
    fn l_i(&self, _worker: usize) -> f64 {
        1.0
    }
    fn l(&self) -> f64 {
        1.0
    }
    fn mu(&self) -> f64 {
        1.0
    }
    fn x_star(&self) -> &[f64] {
        &self.x_star
    }
    fn grad_star(&self, worker: usize) -> &[f64] {
        &self.grad_star[worker]
    }
}

// ------------------------------------------------------------------- tests

/// Steady-state `DcgdShift::step` (DIANA + Rand-K, the common production
/// configuration) performs **zero** heap allocations after warm-up.
#[test]
fn single_process_round_is_allocation_free() {
    let _guard = TEST_LOCK.lock().unwrap();
    let d = 4096;
    let p = MeanProblem::new(d, 4, 1);
    let mut alg = DcgdShift::diana(&p, RandK::with_q(d, 0.01), None, 1);
    // warm-up: fill packet/scratch capacities and thread-local bitmaps
    for _ in 0..5 {
        alg.step(&p);
    }
    let allocs = thread_allocs(|| {
        for _ in 0..10 {
            alg.step(&p);
        }
    });
    assert_eq!(allocs, 0, "DcgdShift::step allocated {allocs} times in 10 rounds");

    // Fixed-shift DCGD too
    let mut alg = DcgdShift::dcgd(&p, RandK::with_q(d, 0.01), 2);
    for _ in 0..5 {
        alg.step(&p);
    }
    let allocs = thread_allocs(|| {
        for _ in 0..10 {
            alg.step(&p);
        }
    });
    assert_eq!(allocs, 0, "dcgd step allocated {allocs} times in 10 rounds");
}

/// The error-fed-back Top-K downlink reuses its compressor scratch, error
/// accumulator and re-pack buffers: steady-state EF rounds are
/// allocation-free too (the Top-K selection scratch is thread-local and
/// warmed by the first rounds).
#[test]
fn ef_downlink_round_is_allocation_free() {
    let _guard = TEST_LOCK.lock().unwrap();
    let d = 4096;
    let p = MeanProblem::new(d, 4, 9);
    let mut alg = DcgdShift::diana(&p, RandK::with_q(d, 0.01), None, 9)
        .with_downlink(Box::new(shiftcomp::compressors::TopK::with_q(d, 0.01)));
    for _ in 0..5 {
        alg.step(&p);
    }
    let allocs = thread_allocs(|| {
        for _ in 0..10 {
            alg.step(&p);
        }
    });
    assert_eq!(allocs, 0, "EF downlink step allocated {allocs} times in 10 rounds");
}

/// The error-fed-back Top-K *uplink* recycles its per-worker accumulator,
/// compressor output and re-pack scratch ([`shiftcomp::ef::EfUplink`]):
/// steady-state EF-uplink rounds are allocation-free once the compressed
/// support has reached its working size.
#[test]
fn ef_uplink_round_is_allocation_free() {
    let _guard = TEST_LOCK.lock().unwrap();
    let d = 4096;
    let p = MeanProblem::new(d, 4, 13);
    let mut alg = DcgdShift::dcgd_ef(&p, shiftcomp::compressors::TopK::with_q(d, 0.01), 13);
    for _ in 0..5 {
        alg.step(&p);
    }
    let allocs = thread_allocs(|| {
        for _ in 0..10 {
            alg.step(&p);
        }
    });
    assert_eq!(allocs, 0, "EF uplink step allocated {allocs} times in 10 rounds");

    // composed with the EF downlink and local-step batching (the batch
    // slots copy the re-packed packets through recycled buffers)
    let mut alg = DcgdShift::diana(&p, RandK::with_q(d, 0.01), None, 14)
        .with_downlink(Box::new(shiftcomp::compressors::TopK::with_q(d, 0.01)))
        .with_local_steps(4)
        .with_uplink_ef();
    for _ in 0..5 {
        alg.step(&p);
    }
    let allocs = thread_allocs(|| {
        for _ in 0..10 {
            alg.step(&p);
        }
    });
    assert_eq!(
        allocs, 0,
        "EF uplink × EF downlink × τ=4 step allocated {allocs} times in 10 rounds"
    );
}

/// Local-step batched rounds recycle their extra scratch too (per-worker
/// sub-step packets, the shared local iterate, the Σ_t est^t accumulator):
/// after warm-up a τ = 4 batched round performs zero heap allocations.
#[test]
fn local_steps_batched_round_is_allocation_free() {
    let _guard = TEST_LOCK.lock().unwrap();
    let d = 2048;
    let p = MeanProblem::new(d, 4, 11);
    let mut alg = DcgdShift::diana(&p, RandK::with_q(d, 0.01), None, 11).with_local_steps(4);
    for _ in 0..5 {
        alg.step(&p);
    }
    let allocs = thread_allocs(|| {
        for _ in 0..10 {
            alg.step(&p);
        }
    });
    assert_eq!(
        allocs, 0,
        "batched DcgdShift::step allocated {allocs} times in 10 rounds"
    );
}

/// GDCI's compressed-iterates loop is allocation-free too.
#[test]
fn gdci_round_is_allocation_free() {
    let _guard = TEST_LOCK.lock().unwrap();
    let d = 2048;
    let p = MeanProblem::new(d, 4, 3);
    let mut alg = Gdci::new(&p, RandK::with_q(d, 0.01), 3);
    for _ in 0..5 {
        alg.step(&p);
    }
    let allocs = thread_allocs(|| {
        for _ in 0..10 {
            alg.step(&p);
        }
    });
    assert_eq!(allocs, 0, "Gdci::step allocated {allocs} times in 10 rounds");
}

/// The threaded coordinator's master thread: frame buffers, decode
/// packets, gather slots and the broadcast Arc are all recycled, and the
/// bounded channels send through preallocated slots. A small constant
/// slack is allowed for channel-internal bookkeeping; the count must not
/// scale with the dimension (i.e. no per-round O(d) or per-packet
/// allocations survive).
#[test]
fn distributed_master_round_is_allocation_light() {
    let _guard = TEST_LOCK.lock().unwrap();
    let rounds = 10u64;
    let mut counts = Vec::new();
    for &d in &[1024usize, 8192] {
        let n = 4;
        let p = Arc::new(MeanProblem::new(d, n, 5));
        let mut runner = DistributedRunner::diana(p.clone(), RandK::with_q(d, 0.01), 5, None);
        for _ in 0..5 {
            runner.step(p.as_ref());
        }
        let allocs = thread_allocs(|| {
            for _ in 0..rounds {
                runner.step(p.as_ref());
            }
        });
        counts.push(allocs);
        assert!(
            allocs <= rounds * 2,
            "master thread allocated {allocs} times in {rounds} rounds (d={d})"
        );
    }
    assert_eq!(
        counts[0], counts[1],
        "master allocations must not scale with dimension: {counts:?}"
    );
}

/// Arming the parallel fold must not break the allocation-light contract:
/// with `master_threads = 4` the pool threads are spawned once at
/// construction, each `FoldPool::run` ships one borrowed-closure pointer
/// per shard over a preallocated rendezvous channel, and the per-packet
/// shard-bound buffers are refilled in place — so the *master thread's*
/// steady-state allocation count stays within the same bound as the
/// serial fold and must not scale with the dimension. (The counting
/// allocator is per-thread: shard threads own no buffers at all, their
/// closures only borrow the master's.)
#[test]
fn distributed_pooled_round_is_allocation_light() {
    let _guard = TEST_LOCK.lock().unwrap();
    let rounds = 10u64;
    let mut counts = Vec::new();
    for &d in &[1024usize, 8192] {
        let n = 4;
        let p = Arc::new(MeanProblem::new(d, n, 19));
        let omega = RandK::with_q(d, 0.01).omega().expect("rand-k is unbiased");
        let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
        let qs: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| Box::new(RandK::with_q(d, 0.01)) as Box<dyn Compressor>)
            .collect();
        let mut runner = DistributedRunner::new(
            p.clone(),
            qs,
            None,
            vec![vec![0.0; d]; n],
            ClusterConfig {
                method: MethodKind::Diana {
                    alpha: ss.alpha,
                    with_c: false,
                },
                gamma: ss.gamma,
                prec: ValPrec::F64,
                seed: 19,
                master_threads: Some(4),
                ..Default::default()
            },
        );
        assert_eq!(runner.fold_threads(), 4);
        // warm-up fills packet capacities, shard-bound buffers and the
        // channel/parking internals of the pool hand-off
        for _ in 0..5 {
            runner.step(p.as_ref());
        }
        let allocs = thread_allocs(|| {
            for _ in 0..rounds {
                runner.step(p.as_ref());
            }
        });
        counts.push(allocs);
        assert!(
            allocs <= rounds * 2,
            "pooled master round allocated {allocs} times in {rounds} rounds (d={d})"
        );
    }
    assert_eq!(
        counts[0], counts[1],
        "pooled master allocations must not scale with dimension: {counts:?}"
    );
}

/// Degraded rounds cost no extra heap: after a crashed worker is
/// quarantined (injected fault + gather deadline), the surviving fleet's
/// steady-state rounds stay within the same allocation-light bound as a
/// healthy cluster — the quarantine's one-off O(d) shift subtraction and
/// failure-record formatting all happen during warm-up, and the reweighted
/// fold reuses the same recycled scratch.
#[test]
fn distributed_degraded_round_is_allocation_light() {
    let _guard = TEST_LOCK.lock().unwrap();
    let rounds = 10u64;
    let d = 2048;
    let n = 4;
    let p = Arc::new(MeanProblem::new(d, n, 17));
    let omega = RandK::with_q(d, 0.01).omega().expect("rand-k is unbiased");
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.01)) as Box<dyn Compressor>)
        .collect();
    let mut runner = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Diana {
                alpha: ss.alpha,
                with_c: false,
            },
            gamma: ss.gamma,
            prec: ValPrec::F64,
            seed: 17,
            faults: Some(FaultPlan::new().crash(3, 2)),
            round_timeout_ms: 200,
            quarantine_after: 1,
            ..Default::default()
        },
    );
    // warm-up runs through the crash round: worker 3 exits at round 2, the
    // gather deadline expires once, and the quarantine's O(d) arithmetic +
    // failure record land here, outside the measured window
    for _ in 0..8 {
        runner.step(p.as_ref());
    }
    let health = runner.health();
    assert_eq!(health.states[3], WorkerState::Quarantined);
    assert_eq!(health.active_workers, n - 1);
    let allocs = thread_allocs(|| {
        for _ in 0..rounds {
            runner.step(p.as_ref());
        }
    });
    assert!(
        allocs <= rounds * 2,
        "degraded master round allocated {allocs} times in {rounds} rounds"
    );
}

/// Periodic dense resyncs recycle everything they touch: the broadcast
/// frame buffer (re-ratcheted to the dense frame during warm-up), the
/// EF-downlink accumulator flush, the overlay clear and the snapshot
/// publication. A schedule interleaving EF delta rounds with resync
/// rounds must stay within the same allocation-light bound, independent
/// of the dimension.
#[test]
fn distributed_resync_rounds_stay_allocation_light() {
    let _guard = TEST_LOCK.lock().unwrap();
    let rounds = 9u64; // resync_every = 3 ⇒ three dense resync rounds
    let mut counts = Vec::new();
    for &d in &[1024usize, 8192] {
        let n = 4;
        let p = Arc::new(MeanProblem::new(d, n, 21));
        let omega = RandK::with_q(d, 0.01).omega().expect("rand-k is unbiased");
        let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
        let qs: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| Box::new(RandK::with_q(d, 0.01)) as Box<dyn Compressor>)
            .collect();
        let mut runner = DistributedRunner::new(
            p.clone(),
            qs,
            None,
            vec![vec![0.0; d]; n],
            ClusterConfig {
                method: MethodKind::Diana {
                    alpha: ss.alpha,
                    with_c: false,
                },
                gamma: ss.gamma,
                prec: ValPrec::F64,
                seed: 21,
                resync_every: 3,
                downlink: Some(Box::new(shiftcomp::compressors::TopK::with_q(d, 0.01))),
                ..Default::default()
            },
        );
        // warm-up spans two full resync periods: the dense-frame buffer
        // capacity, the overlay's full-dimension reserve and the publisher
        // patch slots all reach their working size here
        for _ in 0..6 {
            runner.step(p.as_ref());
        }
        let allocs = thread_allocs(|| {
            for _ in 0..rounds {
                runner.step(p.as_ref());
            }
        });
        counts.push(allocs);
        assert!(
            allocs <= rounds * 2,
            "resync-interleaved master rounds allocated {allocs} times in {rounds} rounds (d={d})"
        );
    }
    assert_eq!(
        counts[0], counts[1],
        "resync allocations must not scale with dimension: {counts:?}"
    );
}

/// The rejoin bootstrap frame is encoded once per round into a buffer
/// recycled inside the downlink state, and every rejoining worker gets the
/// same `Arc` — so a second quarantine → readmission cycle reuses the
/// buffer the first one grew. The measured second rejoin round is bounded
/// by a small constant (the shift-bootstrap clone plus the publisher's
/// pinned-slot fallback), with an allocation count independent of the
/// dimension: an O(d)-count rebuild of the dense frame would scale.
#[test]
fn rejoin_round_reuses_the_recycled_bootstrap_frame() {
    let _guard = TEST_LOCK.lock().unwrap();
    let mut counts = Vec::new();
    for &d in &[1024usize, 8192] {
        let n = 4;
        let p = Arc::new(MeanProblem::new(d, n, 23));
        let omega = RandK::with_q(d, 0.01).omega().expect("rand-k is unbiased");
        let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
        let qs: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| Box::new(RandK::with_q(d, 0.01)) as Box<dyn Compressor>)
            .collect();
        let mut runner = DistributedRunner::new(
            p.clone(),
            qs,
            None,
            vec![vec![0.0; d]; n],
            ClusterConfig {
                method: MethodKind::Diana {
                    alpha: ss.alpha,
                    with_c: false,
                },
                gamma: ss.gamma,
                prec: ValPrec::F64,
                seed: 23,
                faults: Some(FaultPlan::new().straggle(3, 2, 1).straggle(3, 6, 1)),
                round_timeout_ms: 200,
                quarantine_after: 1,
                ..Default::default()
            },
        );
        // first quarantine → rejoin cycle is the warm-up: it grows the
        // bootstrap frame buffer and every other recycled buffer
        for _ in 0..4 {
            runner.step(p.as_ref()); // rounds 0..3; quarantined at round 2
        }
        assert_eq!(runner.health().states[3], WorkerState::Quarantined);
        runner.rejoin(3).expect("worker thread is alive");
        for _ in 0..3 {
            runner.step(p.as_ref()); // rejoin round + 2 more; re-quarantined at round 6
        }
        assert_eq!(runner.health().states[3], WorkerState::Quarantined);
        runner.rejoin(3).expect("worker thread is alive");
        let allocs = thread_allocs(|| {
            runner.step(p.as_ref());
        });
        counts.push(allocs);
        assert!(
            allocs <= 8,
            "second rejoin round allocated {allocs} times (d={d})"
        );
    }
    assert_eq!(
        counts[0], counts[1],
        "rejoin allocations must not scale with dimension: {counts:?}"
    );
}

/// Rand-DIANA with p = 1 refreshes every round, driving the sparse
/// shift-refresh delta and the downlink delta builder through their
/// maximum support during warm-up — after which rounds must stay
/// allocation-free.
#[test]
fn rand_diana_refresh_round_is_allocation_free() {
    let _guard = TEST_LOCK.lock().unwrap();
    let d = 2048;
    let p = MeanProblem::new(d, 4, 7);
    let mut alg = DcgdShift::rand_diana(&p, RandK::with_q(d, 0.01), Some(1.0), 7);
    for _ in 0..5 {
        alg.step(&p);
    }
    let allocs = thread_allocs(|| {
        for _ in 0..10 {
            alg.step(&p);
        }
    });
    assert_eq!(
        allocs, 0,
        "rand-diana refresh step allocated {allocs} times in 10 rounds"
    );
}
