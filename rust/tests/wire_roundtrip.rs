//! Exhaustive wire-format property tests on hand-constructed packets
//! (beyond what compressors happen to emit), including adversarial inputs.

use shiftcomp::compressors::{Packet, ValPrec};
use shiftcomp::util::proptest_lite::{run, Gen};
use shiftcomp::wire;

fn random_packet(g: &mut Gen) -> Packet {
    let d = g.usize_in(1, 300);
    match g.usize_in(0, 6) {
        0 => Packet::Dense(g.vec_mixed_scale(d)),
        1 => {
            let k = g.usize_in(0, d);
            let mut idx: Vec<u32> = Vec::new();
            let mut cur = 0u32;
            for _ in 0..k {
                let step = g.usize_in(1, 3) as u32;
                if (cur + step) as usize > d {
                    break;
                }
                cur += step;
                idx.push(cur - 1);
            }
            let vals = g.vec_mixed_scale(idx.len());
            Packet::Sparse {
                dim: d as u32,
                indices: idx,
                values: vals,
                scale: g.f64_in(-100.0, 100.0),
            }
        }
        2 => {
            let s = g.usize_in(1, 15) as u8;
            Packet::Levels {
                dim: d as u32,
                norm: g.f64_in(0.0, 1e6),
                s,
                signs: (0..d).map(|_| g.bool()).collect(),
                levels: (0..d).map(|_| g.usize_in(0, s as usize) as u8).collect(),
            }
        }
        3 => {
            let s = g.usize_in(1, 200) as u32;
            Packet::LevelsLinear {
                dim: d as u32,
                norm: g.f64_in(0.0, 1e3),
                s,
                signs: (0..d).map(|_| g.bool()).collect(),
                levels: (0..d)
                    .map(|_| g.usize_in(0, (s as usize).min(255)) as u8)
                    .collect(),
            }
        }
        4 => Packet::NatExp {
            dim: d as u32,
            signs: (0..d).map(|_| g.bool()).collect(),
            exps: (0..d)
                .map(|_| {
                    if g.bool() {
                        i8::MIN
                    } else {
                        g.usize_in(0, 250) as i32 as i8
                    }
                })
                .collect(),
        },
        5 => Packet::SignScale {
            dim: d as u32,
            scale: g.f64_in(0.0, 1e3),
            signs: (0..d).map(|_| g.bool()).collect(),
        },
        _ => {
            let mask: Vec<bool> = (0..d).map(|_| g.bool()).collect();
            let nnz = mask.iter().filter(|&&b| b).count();
            Packet::TernaryPkt {
                dim: d as u32,
                scale: g.f64_in(0.0, 1e3),
                mask,
                signs: (0..nnz).map(|_| g.bool()).collect(),
            }
        }
    }
}

#[test]
fn prop_roundtrip_f64_exact() {
    run(200, 0x77133, |g| {
        let pkt = random_packet(g);
        let bytes = wire::encode(&pkt, ValPrec::F64);
        let back = wire::decode(&bytes).map_err(|e| e.to_string())?;
        if back != pkt {
            return Err(format!("roundtrip mutated {pkt:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_roundtrip_f32_structure_preserved() {
    run(120, 0x77134, |g| {
        let pkt = random_packet(g);
        let bytes = wire::encode(&pkt, ValPrec::F32);
        let back = wire::decode(&bytes).map_err(|e| e.to_string())?;
        if back.dim() != pkt.dim() {
            return Err("dim changed".into());
        }
        let a = back.decode();
        let b = pkt.decode();
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            // f32 rounding tolerance, relative to magnitude
            let tol = 2e-6 * y.abs().max(1e-30) + 1e-30;
            // Levels/NatExp/Ternary carry one scale: error compounds once more
            if (x - y).abs() > tol * 4.0 && (y.abs() > 1e-25) {
                return Err(format!("coord {i}: {x} vs {y}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_truncation_always_errors_or_roundtrips() {
    // Chopping bytes off a valid message must produce an error, never a
    // silently wrong packet of the same content length.
    run(80, 0x77135, |g| {
        let pkt = random_packet(g);
        let bytes = wire::encode(&pkt, ValPrec::F64);
        if bytes.len() <= 1 {
            return Ok(());
        }
        let cut = g.usize_in(1, bytes.len() - 1);
        match wire::decode(&bytes[..cut]) {
            Err(_) => Ok(()),
            Ok(decoded) => {
                // tolerated only if truncation removed nothing semantic
                if decoded == pkt {
                    Ok(())
                } else {
                    // a *different* but valid decode is acceptable only when
                    // the packet's own payload genuinely ends early (e.g.
                    // trailing zero-length fields); reject everything else
                    Err(format!(
                        "truncated decode returned a different packet (cut {cut}/{})",
                        bytes.len()
                    ))
                }
            }
        }
    });
}

#[test]
fn garbage_never_panics() {
    run(300, 0x77136, |g| {
        let len = g.usize_in(0, 64);
        let junk: Vec<u8> = (0..len).map(|_| g.usize_in(0, 255) as u8).collect();
        let _ = wire::decode(&junk); // must not panic
        Ok(())
    });
}

/// Word-boundary torture for the word-at-a-time packer: index widths from
/// 1 to 21 bits (dims up to 2^21), support at both ends of the range, and
/// every alignment phase hit by mixing in single-sign runs.
#[test]
fn wide_dim_unaligned_sparse_roundtrips() {
    for pow in 1..=21u32 {
        for extra in [0i64, -1, 1] {
            let dim = ((1i64 << pow) + extra).max(2) as u32;
            let mut indices = vec![0, 1, dim / 3, dim / 2, dim - 2, dim - 1];
            indices.sort_unstable();
            indices.dedup();
            let values: Vec<f64> = (0..indices.len()).map(|i| i as f64 - 2.5).collect();
            let pkt = Packet::Sparse {
                dim,
                indices,
                values,
                scale: 0.75,
            };
            let bytes = wire::encode(&pkt, ValPrec::F64);
            assert_eq!(
                bytes.len(),
                wire::encoded_len(&pkt, ValPrec::F64),
                "dim {dim}: length accounting"
            );
            let back = wire::decode(&bytes).unwrap();
            assert_eq!(back, pkt, "dim {dim}");
        }
    }
}

/// Downlink frames round-trip for every packet shape any compressor emits,
/// and truncations always error.
#[test]
fn prop_down_frames_roundtrip() {
    run(120, 0x77137, |g| {
        let pkt = random_packet(g);
        let kind = match g.usize_in(0, 2) {
            0 => wire::DownKind::Delta,
            1 => wire::DownKind::Resync,
            _ => wire::DownKind::EfDelta,
        };
        let mut buf = vec![0x5Au8; g.usize_in(0, 16)];
        wire::encode_down_into(kind, &pkt, ValPrec::F64, &mut buf);
        let mut out = Packet::Zero { dim: 0 };
        let got = wire::decode_down_into(&buf, &mut out).map_err(|e| e.to_string())?;
        if got != kind {
            return Err(format!("kind mutated: {got:?} vs {kind:?}"));
        }
        if out != pkt {
            return Err(format!("downlink roundtrip mutated {pkt:?}"));
        }
        let cut = g.usize_in(0, buf.len() - 1);
        if wire::decode_down_into(&buf[..cut], &mut out).is_ok() && out != pkt {
            return Err(format!("truncated downlink decoded differently (cut {cut})"));
        }
        Ok(())
    });
}

/// Garbage downlink bytes must never panic.
#[test]
fn down_garbage_never_panics() {
    run(200, 0x77138, |g| {
        let len = g.usize_in(0, 64);
        let junk: Vec<u8> = (0..len).map(|_| g.usize_in(0, 255) as u8).collect();
        let mut out = Packet::Zero { dim: 0 };
        let _ = wire::decode_down_into(&junk, &mut out); // must not panic
        Ok(())
    });
}

/// Batched uplink frames: any sequence of random packets concatenates into
/// one frame and walks back packet-for-packet with a single recycled
/// scratch, consuming exactly the whole frame.
#[test]
fn prop_batch_frames_roundtrip() {
    run(120, 0x77139, |g| {
        let count = g.usize_in(1, 12);
        let pkts: Vec<Packet> = (0..count).map(|_| random_packet(g)).collect();
        let mut buf = vec![0xA5u8; g.usize_in(0, 16)]; // dirty, recycled
        wire::begin_batch_frame(count, &mut buf);
        for pkt in &pkts {
            wire::append_batch_packet(pkt, ValPrec::F64, &mut buf);
        }
        let (n, mut off) = wire::split_batch_frame(&buf).map_err(|e| e.to_string())?;
        if n != count {
            return Err(format!("count mutated: {n} vs {count}"));
        }
        let mut scratch = Packet::Zero { dim: 0 };
        for (i, pkt) in pkts.iter().enumerate() {
            off = wire::decode_batch_packet(&buf, off, &mut scratch)
                .map_err(|e| e.to_string())?;
            if &scratch != pkt {
                return Err(format!("batch packet {i} mutated"));
            }
        }
        if off != buf.len() {
            return Err(format!("walk consumed {off} of {} bytes", buf.len()));
        }
        // truncation anywhere inside the body must error while walking
        let cut = g.usize_in(wire::BATCH_HEADER_BYTES, buf.len() - 1);
        let mut o = wire::BATCH_HEADER_BYTES;
        let mut ok = true;
        for _ in 0..count {
            match wire::decode_batch_packet(&buf[..cut], o, &mut scratch) {
                Ok(next) => o = next,
                Err(_) => {
                    ok = false;
                    break;
                }
            }
        }
        if ok {
            return Err(format!("cut {cut} decoded all {count} packets"));
        }
        Ok(())
    });
}

/// Garbage batch bytes must never panic.
#[test]
fn batch_garbage_never_panics() {
    run(200, 0x7713A, |g| {
        let len = g.usize_in(0, 64);
        let junk: Vec<u8> = (0..len).map(|_| g.usize_in(0, 255) as u8).collect();
        let mut out = Packet::Zero { dim: 0 };
        if let Ok((_, mut off)) = wire::split_batch_frame(&junk) {
            for _ in 0..4 {
                match wire::decode_batch_packet(&junk, off, &mut out) {
                    Ok(next) => off = next,
                    Err(_) => break,
                }
            }
        }
        Ok(())
    });
}
