//! Integration tests: every theorem's qualitative claim, measured.

use shiftcomp::prelude::*;

fn ridge() -> Ridge {
    Ridge::paper_default(77)
}

fn opts(max_rounds: usize, tol: f64) -> RunOpts {
    RunOpts {
        max_rounds,
        tol,
        record_every: 20,
        ..Default::default()
    }
}

/// Theorem 1: DCGD converges linearly *to a neighborhood* whose radius is
/// controlled by γ·(1/n²)Σω‖∇f_i(x*)‖² — measured floor within a modest
/// factor of the theoretical radius.
#[test]
fn thm1_dcgd_neighborhood_matches_theory() {
    let p = ridge();
    let d = p.dim();
    let q = RandK::with_q(d, 0.25);
    let omega = q.omega().unwrap();
    let mut alg = DcgdShift::dcgd(&p, q, 7);
    let gamma = alg.gamma;
    let trace = alg.run(&p, &opts(60_000, 1e-30));
    assert!(!trace.diverged);
    let floor = trace.error_floor();

    let x0 = shiftcomp::algorithms::paper_x0(d, 7);
    let denom = shiftcomp::linalg::dist_sq(&x0, p.x_star());
    let shifts = vec![vec![0.0; d]; p.n_workers()];
    let radius = shiftcomp::theory::dcgd_fixed_neighborhood(
        &p,
        &vec![omega; p.n_workers()],
        &shifts,
        gamma,
    ) / denom;
    assert!(
        floor <= radius * 20.0,
        "floor {floor:e} far above theoretical radius {radius:e}"
    );
    assert!(
        floor >= radius / 1e5,
        "floor {floor:e} suspiciously far below radius {radius:e}"
    );
}

/// Theorem 1 with a *good fixed shift* (h_i = ∇f_i(x*)): neighborhood
/// vanishes even without any learning.
#[test]
fn thm1_optimal_fixed_shift_is_exact() {
    let p = ridge();
    let d = p.dim();
    let shifts: Vec<Vec<f64>> = (0..p.n_workers()).map(|i| p.grad_star(i).to_vec()).collect();
    let mut alg = DcgdShift::fixed_shift(&p, RandK::with_q(d, 0.25), shifts, 9);
    let trace = alg.run(&p, &opts(120_000, 1e-20));
    assert!(trace.converged, "floor {:e}", trace.error_floor());
}

/// Theorem 2: DCGD-STAR with a contractive C reaches the exact optimum and
/// its step size beats plain DCGD's.
#[test]
fn thm2_star_with_topk_compressor() {
    let p = ridge();
    let d = p.dim();
    let c: Box<dyn Compressor> = Box::new(TopK::with_q(d, 0.5));
    let mut alg = DcgdShift::star(&p, RandK::with_q(d, 0.25), Some(c), 11);
    let trace = alg.run(&p, &opts(120_000, 1e-20));
    assert!(trace.converged, "floor {:e}", trace.error_floor());
}

/// Theorem 3 (generalized DIANA): biased C_i in the shift update still
/// converges exactly, and the effective ω(1−δ) yields a larger α.
#[test]
fn thm3_diana_with_biased_c() {
    let p = ridge();
    let d = p.dim();
    let c: Box<dyn Compressor> = Box::new(TopK::with_q(d, 0.5));
    let mut alg = DcgdShift::diana(&p, RandK::with_q(d, 0.25), Some(c), 13);
    let trace = alg.run(&p, &opts(120_000, 1e-18));
    assert!(
        trace.converged || trace.error_floor() < 1e-14,
        "floor {:e}",
        trace.error_floor()
    );
}

/// Theorem 4: Rand-DIANA converges exactly on the non-interpolating ridge,
/// and its empirical rate is no worse than the theoretical bound by a large
/// factor (sanity of the rate formula, not exactness).
#[test]
fn thm4_rand_diana_rate_sanity() {
    let p = ridge();
    let d = p.dim();
    let q = RandK::with_q(d, 0.5);
    let omega = q.omega().unwrap();
    let pr = shiftcomp::theory::rand_diana_default_p(omega);
    let ss = shiftcomp::theory::rand_diana(&p, omega, &vec![pr; p.n_workers()], None);
    let mut alg = DcgdShift::rand_diana(&p, q, None, 15);
    let trace = alg.run(&p, &opts(120_000, 1e-16));
    assert!(trace.converged, "floor {:e}", trace.error_floor());
    // measured rounds ≤ 5× the theoretical bound for the target
    let measured = trace.rounds_to_tol(1e-10).unwrap() as f64;
    let bound = (1.0f64 / 1e-10).ln() / -(ss.rate.ln());
    assert!(
        measured <= bound * 5.0,
        "measured {measured} vs theory bound {bound}"
    );
}

/// Theorems 5/6 qualitative: GDCI floors, VR-GDCI doesn't; the improved
/// GDCI steps converge much faster than the Chraibi-et-al rate.
#[test]
fn thm5_thm6_gdci_family() {
    let p = ridge();
    let d = p.dim();
    let o = opts(60_000, 1e-26);
    let gdci = Gdci::new(&p, RandK::with_q(d, 0.5), 17).run(&p, &o);
    let old = Gdci::new_chraibi(&p, RandK::with_q(d, 0.5), 17).run(&p, &o);
    let vr = VrGdci::new(&p, RandK::with_q(d, 0.5), 17).run(&p, &o);

    assert!(!gdci.diverged && !old.diverged && !vr.diverged);
    // VR removes the floor
    assert!(
        vr.error_floor() < gdci.error_floor() * 1e-2,
        "vr {:e} vs gdci {:e}",
        vr.error_floor(),
        gdci.error_floor()
    );
    // our steps reach the GDCI floor much faster than the old rate:
    // compare error at the same (early) round
    let at = |t: &Trace, round: usize| {
        t.records
            .iter()
            .find(|r| r.round >= round)
            .map(|r| r.rel_err)
            .unwrap_or(f64::NAN)
    };
    let ours_err = at(&gdci, 3_000);
    let old_err = at(&old, 3_000);
    assert!(
        ours_err < old_err * 1e-2 || old_err > 0.5,
        "at round 3000: ours {ours_err:e}, chraibi {old_err:e}"
    );
}

/// Interpolation regime: DCGD alone reaches the exact optimum (the paper's
/// Theorem-1 discussion) — no shifts needed.
#[test]
fn interpolation_makes_dcgd_exact() {
    let p = Quadratic::interpolating(30, 6, 1.0, 15.0, 19);
    let mut alg = DcgdShift::dcgd(&p, RandK::with_q(30, 0.2), 19);
    let trace = alg.run(&p, &opts(60_000, 1e-20));
    assert!(trace.converged, "floor {:e}", trace.error_floor());
}

/// Bits-efficiency headline (Figure 1 left, shape): at q = 0.1,
/// Rand-DIANA matches DIANA in rounds and beats it under the paper's
/// gradient-message bit accounting. (Under *total*-traffic accounting that
/// also counts Rand-DIANA's dense shift refreshes, DIANA wins — both
/// conventions are reported; see EXPERIMENTS.md §Deviations.)
#[test]
fn rand_diana_beats_diana_in_message_bits_at_low_q() {
    let p = ridge();
    let d = p.dim();
    let o = opts(200_000, 1e-10);
    let diana = DcgdShift::diana(&p, RandK::with_q(d, 0.1), None, 21).run(&p, &o);
    let rand = DcgdShift::rand_diana(&p, RandK::with_q(d, 0.1), None, 21).run(&p, &o);
    let db = diana.bits_to_tol_messages_only(1e-8);
    let rb = rand.bits_to_tol_messages_only(1e-8);
    assert!(db.is_some() && rb.is_some(), "both must reach 1e-8: {db:?} {rb:?}");
    assert!(
        rb.unwrap() < db.unwrap(),
        "rand-diana {rb:?} should beat diana {db:?} (message bits) at q=0.1"
    );
    // rounds within 1.25× of each other (complexities match, Table 1)
    let dr = diana.rounds_to_tol(1e-8).unwrap() as f64;
    let rr = rand.rounds_to_tol(1e-8).unwrap() as f64;
    assert!(rr <= dr * 1.25, "rounds: rand {rr} vs diana {dr}");
}

/// Logistic regression (Figure 4 shape): both VR methods drive the error
/// well below any DCGD floor on the w2a-like problem.
#[test]
fn logistic_vr_methods_converge() {
    let p = Logistic::w2a_default(10, 5);
    let d = p.dim();
    let o = opts(40_000, 1e-10);
    let diana = DcgdShift::diana(&p, RandK::with_q(d, 0.5), None, 23).run(&p, &o);
    let rand = DcgdShift::rand_diana(&p, RandK::with_q(d, 0.5), None, 23).run(&p, &o);
    assert!(
        diana.converged || diana.final_relative_error() < 1e-6,
        "diana {:e}",
        diana.final_relative_error()
    );
    assert!(
        rand.converged || rand.final_relative_error() < 1e-6,
        "rand {:e}",
        rand.final_relative_error()
    );
}

/// Heterogeneous fleets (§3.2.1's bandwidth remark): per-worker Rand-K at
/// different q, DIANA shift learning, exact convergence with step sizes
/// driven by the worst-case ω_i.
#[test]
fn heterogeneous_compressors_converge() {
    use shiftcomp::algorithms::ShiftRule;
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|i| {
            let q = 0.8 - 0.6 * (i as f64) / (n as f64 - 1.0); // 0.8 → 0.2
            Box::new(RandK::with_q(d, q)) as Box<dyn Compressor>
        })
        .collect();
    let omegas: Vec<f64> = qs.iter().map(|q| q.omega().unwrap()).collect();
    let ss = shiftcomp::theory::diana(&p, &omegas, &vec![0.0; n], 2.0);
    let rules = (0..n)
        .map(|_| ShiftRule::Diana {
            alpha: ss.alpha,
            c: None,
        })
        .collect();
    let mut alg = DcgdShift::custom(
        "diana-hetero",
        &p,
        qs,
        rules,
        vec![vec![0.0; d]; n],
        ss.gamma,
        31,
    );
    let trace = alg.run(&p, &opts(120_000, 1e-14));
    assert!(
        trace.converged || trace.error_floor() < 1e-12,
        "floor {:e}",
        trace.error_floor()
    );
}

/// Natural dithering end-to-end: the compressor the paper grid-searches in
/// Figure 1 right drives DIANA to exact convergence.
#[test]
fn diana_with_natural_dithering_converges() {
    let p = ridge();
    let d = p.dim();
    let mut alg =
        DcgdShift::diana(&p, shiftcomp::compressors::NaturalDithering::l2(d, 6), None, 33);
    let trace = alg.run(&p, &opts(80_000, 1e-14));
    assert!(
        trace.converged || trace.error_floor() < 1e-12,
        "floor {:e}",
        trace.error_floor()
    );
}
