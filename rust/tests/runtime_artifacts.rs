//! Whole-stack integration: the AOT artifacts (L1 Pallas → L2 JAX → HLO)
//! executed through PJRT must agree with the native Rust implementations.
//!
//! These tests are gated on `artifacts/manifest.json` (run `make artifacts`
//! first); they *skip* rather than fail when artifacts are absent so the
//! pure-Rust test suite stays green on a fresh checkout.

use shiftcomp::compressors::{Compressor, RandK};
use shiftcomp::linalg::Mat;
use shiftcomp::problems::Problem;
use shiftcomp::runtime::oracles::{HloNatDither, HloShiftedCompress};
use shiftcomp::runtime::{Engine, HloRidgeOracle, LmSession};
use shiftcomp::util::rng::Pcg64;

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping runtime test: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Engine::cpu("artifacts").expect("engine"))
}

#[test]
fn hlo_ridge_grad_matches_rust_problem() {
    let Some(engine) = engine() else { return };
    let oracle = HloRidgeOracle::new(&engine).expect("ridge oracle");
    assert_eq!(oracle.d, 80);
    assert_eq!(oracle.m_i, 10);

    // Build a rust-side worker with the same shapes and compare gradients.
    let mut rng = Pcg64::new(1);
    let m_i = oracle.m_i;
    let d = oracle.d;
    let mut a = Mat::zeros(m_i, d);
    rng.fill_normal(&mut a.data);
    let y: Vec<f64> = (0..m_i).map(|_| rng.normal()).collect();
    let lam = 0.01;
    let n = 10.0;
    let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();

    let got = oracle.grad(&x, &a.data, &y, lam, n).expect("hlo grad");

    // native: n·Aᵀ(Ax − y) + λx
    let mut resid = a.matvec(&x);
    for (r, t) in resid.iter_mut().zip(y.iter()) {
        *r -= t;
    }
    let mut want = a.t_matvec(&resid);
    for j in 0..d {
        want[j] = n * want[j] + lam * x[j];
    }

    for j in 0..d {
        assert!(
            (got[j] - want[j]).abs() <= 1e-9 * (1.0 + want[j].abs()),
            "coord {j}: hlo {} vs rust {}",
            got[j],
            want[j]
        );
    }
}

#[test]
fn hlo_ridge_grad_matches_full_problem_stack() {
    let Some(engine) = engine() else { return };
    let oracle = HloRidgeOracle::new(&engine).expect("oracle");
    // The actual paper problem: feed worker 0's shard through PJRT and
    // compare against Problem::local_grad_into.
    let p = shiftcomp::problems::Ridge::paper_default(4);
    let mut rng = Pcg64::new(5);
    let x: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
    let mut want = vec![0.0; p.dim()];
    p.local_grad_into(0, &x, &mut want);

    // reconstruct worker 0's shard exactly as Ridge::from_data partitions
    let ds = shiftcomp::data::make_regression(&shiftcomp::data::RegressionOpts {
        n_samples: 100,
        n_features: 80,
        seed: 4,
        ..Default::default()
    });
    let mut part_rng = Pcg64::with_stream(4, 0x9a47);
    let parts = shiftcomp::data::partition_evenly(100, 10, &mut part_rng);
    let rows = &parts[0];
    let mut a = Vec::with_capacity(rows.len() * 80);
    let mut yv = Vec::with_capacity(rows.len());
    for &r in rows {
        a.extend_from_slice(ds.a.row(r));
        yv.push(ds.y[r]);
    }
    let got = oracle.grad(&x, &a, &yv, 0.01, 10.0).expect("hlo grad");
    for j in 0..p.dim() {
        assert!(
            (got[j] - want[j]).abs() <= 1e-8 * (1.0 + want[j].abs()),
            "coord {j}: {} vs {}",
            got[j],
            want[j]
        );
    }
}

#[test]
fn hlo_shifted_compress_matches_rand_k_semantics() {
    let Some(engine) = engine() else { return };
    let kernel = HloShiftedCompress::new(&engine).expect("kernel");
    let d = kernel.d;
    let mut rng = Pcg64::new(7);
    let g: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let h: Vec<f64> = (0..d).map(|_| rng.normal()).collect();

    // Rand-K mask with k = 8 → scale d/k; decoded result must equal
    // h + mask·(g−h)·scale, which is exactly h + Q(g−h) for Rand-K.
    let k = 8;
    let idx = rng.subset(d, k);
    let mut mask = vec![0.0; d];
    for &i in &idx {
        mask[i as usize] = 1.0;
    }
    let scale = d as f64 / k as f64;
    let got = kernel.apply(&g, &h, &mask, scale).expect("hlo");
    for j in 0..d {
        let want = h[j] + mask[j] * (g[j] - h[j]) * scale;
        assert!(
            (got[j] - want).abs() <= 1e-12 * (1.0 + want.abs()),
            "coord {j}: {} vs {want}",
            got[j]
        );
    }
    // exactness at the shift: g = h ⇒ out = h bit-for-bit
    let same = kernel.apply(&h, &h, &mask, scale).expect("hlo");
    assert_eq!(same, h);
}

#[test]
fn hlo_nat_dither_is_on_grid_and_unbiased_shape() {
    let Some(engine) = engine() else { return };
    let kernel = HloNatDither::new(&engine).expect("kernel");
    let d = kernel.d;
    let s = kernel.s as i32;
    let mut rng = Pcg64::new(9);
    let x: Vec<f64> = (0..d).map(|_| rng.normal() * 2.0).collect();
    let norm = shiftcomp::linalg::nrm2(&x);
    let u: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
    let out = kernel.quantize(&x, &u, norm).expect("hlo");
    for (j, &v) in out.iter().enumerate() {
        if v != 0.0 {
            let t = v.abs() / norm;
            let l = t.log2();
            assert!(
                (l - l.round()).abs() < 1e-9,
                "coord {j}: {t} not a binary level"
            );
            assert!(l.round() >= (1 - s) as f64 - 1e-9 && l.round() <= 0.0 + 1e-9);
            assert_eq!(v >= 0.0, x[j] >= 0.0, "sign preserved");
        }
    }
}

#[test]
fn lm_session_executes_and_losses_are_sane() {
    let Some(engine) = engine() else { return };
    let session = LmSession::new(&engine).expect("session");
    assert!(session.param_count > 3_000_000);
    let params = session.initial_params().expect("init params");
    assert_eq!(params.len(), session.param_count);

    let mut rng = Pcg64::new(11);
    let tokens: Vec<i32> = (0..session.batch * (session.seq + 1))
        .map(|_| rng.below(session.vocab as u64) as i32)
        .collect();
    let (loss, grads) = session.step(&params, &tokens).expect("lm step");
    // fresh init ⇒ loss ≈ ln(vocab)
    let expect = (session.vocab as f32).ln();
    assert!(
        (loss - expect).abs() < 0.5,
        "initial loss {loss} vs ln V = {expect}"
    );
    assert!(grads.iter().all(|g| g.is_finite()));
    let gnorm: f32 = grads.iter().map(|g| g * g).sum::<f32>().sqrt();
    assert!(gnorm > 1e-3, "gradients should be nonzero: {gnorm}");

    // one SGD step reduces the loss on the same batch
    let params2: Vec<f32> = params
        .iter()
        .zip(grads.iter())
        .map(|(p, g)| p - 0.5 * g)
        .collect();
    let (loss2, _) = session.step(&params2, &tokens).expect("lm step 2");
    assert!(loss2 < loss, "descent failed: {loss} → {loss2}");
}

#[test]
fn lm_compressed_round_preserves_descent() {
    // Mini end-to-end: one DIANA-compressed round through the real
    // artifact must still make progress comparable to the dense round.
    let Some(engine) = engine() else { return };
    let session = LmSession::new(&engine).expect("session");
    let params = session.initial_params().expect("init");
    let p = session.param_count;

    let mut rng = Pcg64::new(13);
    let tokens: Vec<i32> = (0..session.batch * (session.seq + 1))
        .map(|_| rng.below(session.vocab as u64) as i32)
        .collect();
    let (loss0, grads) = session.step(&params, &tokens).expect("step");

    // compress the gradient with rand-k(1%) + zero shift
    let comp = RandK::with_q(p, 0.05);
    let g64: Vec<f64> = grads.iter().map(|&v| v as f64).collect();
    let decoded = comp.compress(&mut rng, &g64).decode();
    let params2: Vec<f32> = params
        .iter()
        .zip(decoded.iter())
        .map(|(pp, g)| pp - 0.25 * (*g as f32))
        .collect();
    let (loss1, _) = session.step(&params2, &tokens).expect("step");
    assert!(
        loss1 < loss0 + 0.05,
        "compressed step exploded: {loss0} → {loss1}"
    );
}
