//! Chaos suite: deterministic fault injection against the threaded
//! coordinator.
//!
//! Four properties are pinned here, one per test:
//!
//! 1. a seeded multi-fault schedule never deadlocks or poisons the master,
//!    and the surviving subset still optimizes its own objective;
//! 2. a crash degrades the trajectory **bit-identically** to a
//!    single-process [`DcgdShift`] mirror quarantined at the same round —
//!    the shift-consistent reweighted aggregate is the same math on both
//!    drivers;
//! 3. a quarantined straggler re-admitted through the dense-resync rejoin
//!    returns to bit-equality — iterate, shift replica and worker-private
//!    state (via [`WorkerCommand::Inspect`]) all match the mirror;
//! 4. quarantine + rejoin flushes the worker's EF uplink accumulator the
//!    way a resync does (EF-BV state-reset semantics): after readmission
//!    the accumulator holds exactly the fresh residual `m − C(m)`, not
//!    stale pre-quarantine mass.

use std::sync::Arc;

use shiftcomp::algorithms::{Algorithm, DcgdShift};
use shiftcomp::compressors::{Compressor, RandK, TopK, ValPrec};
use shiftcomp::coordinator::{
    ClusterConfig, DistributedRunner, FailureClass, FaultPlan, MethodKind, WorkerState,
};
use shiftcomp::ef::EfUplink;
use shiftcomp::linalg::{axpy, nrm2};
use shiftcomp::problems::{Problem, Quadratic, Ridge};
use shiftcomp::util::rng::Pcg64;

/// A generous gather deadline for tests: rounds on these tiny problems
/// take microseconds, so only an injected fault can ever hit it, while a
/// loaded CI machine still has ~3 orders of magnitude of slack before a
/// healthy worker is misclassified.
const TEST_TIMEOUT_MS: u64 = 1_000;

fn cluster(
    p: &Arc<dyn Problem>,
    method: MethodKind,
    gamma: f64,
    q: &(impl Compressor + Clone + 'static),
    seed: u64,
    uplink_ef: bool,
    faults: FaultPlan,
) -> DistributedRunner {
    let d = p.dim();
    let n = p.n_workers();
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
        .collect();
    DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method,
            gamma,
            prec: ValPrec::F64,
            seed,
            uplink_ef,
            faults: Some(faults),
            round_timeout_ms: TEST_TIMEOUT_MS,
            quarantine_after: 1,
            ..Default::default()
        },
    )
}

/// ‖(1/|S|) Σ_{i∈S} ∇f_i(x)‖ over the active subset S — the gradient of
/// the objective a degraded fleet is actually minimizing.
fn active_subset_grad_norm(p: &dyn Problem, active: &[usize], x: &[f64]) -> f64 {
    assert!(!active.is_empty());
    let d = p.dim();
    let mut g = vec![0.0; d];
    let mut tmp = vec![0.0; d];
    for &i in active {
        p.local_grad_into(i, x, &mut tmp);
        axpy(1.0 / active.len() as f64, &tmp, &mut g);
    }
    nrm2(&g)
}

/// (1) A seeded fault schedule — crashes, garbage frames, corrupt
/// downlinks, straggler windows, all drawn from one reproducible stream —
/// must never deadlock or poison the master: every round completes, and
/// the survivors drive *their* mean objective to stationarity.
#[test]
fn seeded_fault_plan_survivors_converge() {
    let d = 12;
    let n = 8;
    let p: Arc<dyn Problem> = Arc::new(Quadratic::random(d, n, 1.0, 10.0, 5));
    let omega = RandK::with_q(d, 0.5).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    // the theory step is tuned for the full fleet; a degraded fleet sees a
    // larger effective ω/n, so run conservatively — convergence is the
    // claim here, not rate optimality
    let gamma = ss.gamma * 0.25;
    let plan = FaultPlan::seeded(7, n, 20);
    assert!(
        !plan.faults.is_empty(),
        "seed 7 must schedule at least one fault for this test to bite"
    );
    let mut dist = cluster(
        &p,
        MethodKind::Diana {
            alpha: ss.alpha,
            with_c: false,
        },
        gamma,
        &RandK::with_q(d, 0.5),
        5,
        false,
        plan,
    );
    let x0 = dist.x().to_vec();
    for k in 0..800 {
        dist.try_step(p.as_ref())
            .unwrap_or_else(|f| panic!("round {k} must survive injected faults: {f}"));
    }
    let health = dist.health();
    let active: Vec<usize> = health
        .states
        .iter()
        .enumerate()
        .filter(|(_, s)| **s == WorkerState::Active)
        .map(|(i, _)| i)
        .collect();
    assert!(active.contains(&0), "worker 0 is never faulted");
    assert!(
        health.degraded_rounds > 0,
        "the schedule must actually have degraded some rounds"
    );
    assert!(dist.x().iter().all(|v| v.is_finite()));
    let g0 = active_subset_grad_norm(p.as_ref(), &active, &x0);
    let g1 = active_subset_grad_norm(p.as_ref(), &active, dist.x());
    assert!(
        g1 <= 1e-4 * g0.max(1.0),
        "survivors must converge on their subset objective: ‖g‖ {g0:.3e} → {g1:.3e}"
    );
}

/// (2) Crashing worker 3 at round 12 must leave the survivors
/// bit-identical to a single-process mirror that quarantines the same
/// worker before the same round: the deadline quarantine subtracts
/// `h_3` from the running shift sum and reweights to `1/9` with exactly
/// the fp operations the mirror performs.
#[test]
fn crash_matches_degraded_mirror_bit_for_bit() {
    let p = Arc::new(Ridge::paper_default(3));
    let d = p.dim();
    let n = p.n_workers();
    let (crashed, crash_round, rounds) = (3usize, 12usize, 40usize);

    let mut single = DcgdShift::dcgd(p.as_ref(), RandK::with_q(d, 0.3), 11);
    let gamma = single.gamma;
    let pd: Arc<dyn Problem> = p.clone();
    let mut dist = cluster(
        &pd,
        MethodKind::Fixed,
        gamma,
        &RandK::with_q(d, 0.3),
        11,
        false,
        FaultPlan::new().crash(crashed, crash_round),
    );

    for k in 0..rounds {
        if k == crash_round {
            single.quarantine_worker(crashed);
        }
        let ss = single.step(p.as_ref());
        let sd = dist
            .try_step(p.as_ref())
            .unwrap_or_else(|f| panic!("round {k}: crash must not be fatal: {f}"));
        assert_eq!(single.x(), dist.x(), "iterates diverged at round {k}");
        assert_eq!(
            ss.active_workers, sd.active_workers,
            "reporter counts diverged at round {k}"
        );
        if k >= crash_round {
            assert_eq!(sd.active_workers, n - 1);
        }
    }

    let health = dist.health();
    assert_eq!(health.states[crashed], WorkerState::Quarantined);
    assert_eq!(health.active_workers, n - 1);
    assert_eq!(health.degraded_rounds, rounds - crash_round);
    // the failure names its class: the crash surfaced as a gather-deadline
    // miss (the channel disconnect is only observable on a later send)
    let f = dist.last_failure(crashed).expect("failure recorded");
    assert_eq!(f.class, FailureClass::Timeout);
    assert_eq!(f.round, crash_round);
    let msg = f.to_string();
    assert!(
        msg.contains("[timeout]") && msg.contains("worker 3"),
        "display must name worker and class: {msg}"
    );
}

/// (3) A straggler quarantined at its deadline miss and re-admitted
/// through [`DistributedRunner::rejoin`] returns to bit-equality with a
/// mirror that skipped the same rounds: the dense-resync bootstrap
/// overwrites the worker's frozen replica and shift, after which
/// iterates, master-side shift replicas and the worker's private state
/// (inspected over the wire) all match.
#[test]
fn straggler_rejoins_bit_equal() {
    let p = Arc::new(Ridge::paper_default(3));
    let d = p.dim();
    let n = p.n_workers();
    // straggle window covers rounds 5..8; quarantined at its first miss
    let (straggler, from, window) = (2usize, 5usize, 3usize);

    let omega = RandK::with_q(d, 0.3).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let mut single = DcgdShift::diana(p.as_ref(), RandK::with_q(d, 0.3), None, 13);
    let gamma = single.gamma;
    let pd: Arc<dyn Problem> = p.clone();
    let mut dist = cluster(
        &pd,
        MethodKind::Diana {
            alpha: ss.alpha,
            with_c: false,
        },
        gamma,
        &RandK::with_q(d, 0.3),
        13,
        false,
        FaultPlan::new().straggle(straggler, from, window),
    );

    // healthy prefix + degraded middle: quarantine the mirror at the
    // straggler's first missed round
    for k in 0..from + window + 1 {
        if k == from {
            single.quarantine_worker(straggler);
        }
        single.step(p.as_ref());
        dist.try_step(p.as_ref())
            .unwrap_or_else(|f| panic!("round {k}: straggle must not be fatal: {f}"));
        assert_eq!(single.x(), dist.x(), "iterates diverged at round {k}");
    }
    assert_eq!(dist.health().states[straggler], WorkerState::Quarantined);
    assert_eq!(
        dist.last_failure(straggler).unwrap().class,
        FailureClass::Timeout
    );

    // readmission: the next distributed round ships the Rejoin bootstrap;
    // the mirror re-activates the same worker before the same step
    dist.rejoin(straggler).expect("straggler thread is alive");
    single.rejoin_worker(straggler);
    let mut x_before_last = Vec::new();
    for k in 0..6 {
        x_before_last = dist.x().to_vec();
        single.step(p.as_ref());
        dist.try_step(p.as_ref())
            .unwrap_or_else(|f| panic!("post-rejoin round {k} failed: {f}"));
        assert_eq!(single.x(), dist.x(), "post-rejoin divergence at round {k}");
    }
    assert_eq!(dist.health().active_workers, n);
    assert!(dist.health().states.iter().all(|s| *s == WorkerState::Active));

    // worker-private state over the wire: the replica is the iterate the
    // last round computed gradients at, the shift matches the mirror's
    let snap = dist.worker_snapshot(straggler);
    assert_eq!(snap.x_replica, x_before_last, "worker replica diverged");
    assert_eq!(snap.h, single.shift(straggler), "worker shift diverged");
    assert_eq!(
        dist.shift(straggler),
        single.shift(straggler),
        "master-side shift replica diverged"
    );
}

/// (4) EF-BV state-reset semantics: quarantine + rejoin must flush the
/// worker's EF uplink accumulator exactly like a dense resync does. After
/// readmission the accumulator holds the fresh one-round residual
/// `m − TopK(m)` bit for bit — stale pre-quarantine mass would change the
/// Top-K support and leave a different residual.
#[test]
fn quarantine_rejoin_flushes_ef_uplink_accumulator() {
    let p = Arc::new(Ridge::paper_default(3));
    let d = p.dim();
    let n = p.n_workers();
    let (straggler, from, window) = (1usize, 6usize, 2usize);
    let q = TopK::with_q(d, 0.1);
    let delta = q.delta().unwrap();
    let ss = shiftcomp::theory::ef_uplink(p.as_ref(), &vec![delta; n]);
    let pd: Arc<dyn Problem> = p.clone();
    let mut dist = cluster(
        &pd,
        MethodKind::Fixed,
        ss.gamma,
        &q,
        23,
        true,
        FaultPlan::new().straggle(straggler, from, window),
    );

    // healthy prefix: Top-K at q = 0.1 drops 90% of coordinates every
    // round, so the accumulator must carry mass before the fault
    for _ in 0..from {
        dist.try_step(p.as_ref()).unwrap();
    }
    let pre = dist.worker_snapshot(straggler);
    let pre_err = pre.uplink_error.expect("EF armed");
    assert!(
        pre_err.iter().any(|v| *v != 0.0),
        "accumulator must be nonzero before quarantine for the flush to matter"
    );

    // the straggle window: first miss quarantines, second round runs
    // degraded with no command to the straggler
    for _ in 0..window {
        dist.try_step(p.as_ref()).unwrap();
    }
    assert_eq!(dist.health().states[straggler], WorkerState::Quarantined);

    // rejoin: the bootstrap resync flushes the accumulator, then the
    // worker answers the round like any freshly synced worker
    dist.rejoin(straggler).expect("straggler thread is alive");
    let x_k = dist.x().to_vec();
    let h_boot = dist.shift(straggler).to_vec();
    dist.try_step(p.as_ref()).unwrap();

    let snap = dist.worker_snapshot(straggler);
    assert_eq!(snap.x_replica, x_k, "rejoin bootstrap must resync the replica");
    // replay the worker's EF round from a *fresh* accumulator:
    // m = ∇f_i(x_k) − h_i, ship TopK(m), keep e = m − TopK(m)
    let mut m = vec![0.0; d];
    p.local_grad_into(straggler, &x_k, &mut m);
    axpy(-1.0, &h_boot, &mut m);
    let mut fresh = EfUplink::new(d);
    // Top-K is deterministic; the stream is only a signature requirement
    let mut rng = Pcg64::with_stream(0, 0);
    fresh.fold_and_compress(&q, &mut rng, &m, ValPrec::F64);
    let expected = fresh.error();
    let got = snap.uplink_error.expect("EF armed");
    assert_eq!(got.len(), expected.len());
    for j in 0..d {
        assert_eq!(
            got[j].to_bits(),
            expected[j].to_bits(),
            "coord {j}: accumulator must equal the fresh-state residual \
             (stale mass would shift the Top-K support)"
        );
    }
}

/// (5) Semi-async composition. Two pins:
///
/// * partial participation + a crash: the cluster with a seeded 0.6
///   sampler degrades **bit-identically** to a mirror that replays the
///   same sampler stream and quarantines the same worker before the same
///   round — the reporter set is `S_k ∩ active` on both drivers. Worker 0
///   is crashed deliberately because the sampler's worker-0-clean
///   guarantee makes its first commanded round (= the crash round)
///   deterministic.
/// * an m = n−1 quorum + a straggler: rounds the straggler misses close
///   by quorum instead of eating the gather deadline (liveness), a
///   quorum-closed miss gets one round of grace
///   (`quarantine_after + 1` misses — a merely-late worker must not be
///   confused with a dead one), and the rejoin path composes: after
///   readmission the whole fleet is Active again.
#[test]
fn semi_async_composes_with_quarantine_and_rejoin() {
    // ---- participation × crash ≡ the seeded degraded mirror
    let p = Arc::new(Ridge::paper_default(3));
    let d = p.dim();
    let n = p.n_workers();
    let (crashed, crash_round) = (0usize, 12usize);
    let mut single = DcgdShift::dcgd(p.as_ref(), RandK::with_q(d, 0.3), 17)
        .with_participation(0.6);
    let gamma = single.gamma;
    let pd: Arc<dyn Problem> = p.clone();
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.3)) as Box<dyn Compressor>)
        .collect();
    let mut dist = DistributedRunner::new(
        pd.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Fixed,
            gamma,
            prec: ValPrec::F64,
            seed: 17,
            participation: Some(0.6),
            faults: Some(FaultPlan::new().crash(crashed, crash_round)),
            round_timeout_ms: TEST_TIMEOUT_MS,
            quarantine_after: 1,
            ..Default::default()
        },
    );
    for k in 0..30 {
        if k == crash_round {
            single.quarantine_worker(crashed);
        }
        let ss = single.step(p.as_ref());
        let sd = dist
            .try_step(p.as_ref())
            .unwrap_or_else(|f| panic!("round {k}: crash under participation fatal: {f}"));
        assert_eq!(single.x(), dist.x(), "iterates diverged at round {k}");
        assert_eq!(
            ss.active_workers, sd.active_workers,
            "reporter counts diverged at round {k}"
        );
    }
    let health = dist.health();
    assert_eq!(health.states[crashed], WorkerState::Quarantined);
    assert_eq!(health.active_workers, n - 1);
    let f = dist.last_failure(crashed).expect("failure recorded");
    assert_eq!(f.class, FailureClass::Timeout);
    assert_eq!(f.round, crash_round, "worker 0 is always sampled, so the \
         crash surfaces at exactly the crash round");

    // ---- quorum × straggle: fast closes, one-round grace, rejoin
    let (straggler, from, window) = (2usize, 8usize, 2usize);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.3)) as Box<dyn Compressor>)
        .collect();
    let mut dist = DistributedRunner::new(
        pd,
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Fixed,
            gamma,
            prec: ValPrec::F64,
            seed: 19,
            quorum: Some(n - 1),
            staleness: true,
            faults: Some(FaultPlan::new().straggle(straggler, from, window)),
            round_timeout_ms: TEST_TIMEOUT_MS,
            quarantine_after: 1,
            ..Default::default()
        },
    );
    for k in 0..from {
        dist.try_step(p.as_ref())
            .unwrap_or_else(|f| panic!("healthy round {k} failed: {f}"));
    }
    // first straggled round: the quorum closes the round without the
    // straggler and without waiting out the deadline — and a
    // quorum-closed miss is NOT yet a quarantine (one round of grace)
    let t0 = std::time::Instant::now();
    dist.try_step(p.as_ref())
        .unwrap_or_else(|f| panic!("first straggled round failed: {f}"));
    let first_straggled = t0.elapsed();
    assert!(
        first_straggled.as_millis() < TEST_TIMEOUT_MS as u128,
        "a quorum close must beat the {TEST_TIMEOUT_MS} ms deadline, took {first_straggled:?}"
    );
    assert_eq!(
        dist.health().states[straggler],
        WorkerState::Active,
        "a single quorum-closed miss gets grace, not quarantine"
    );
    // second straggled round: two consecutive quorum-closed misses cross
    // the quarantine_after + 1 threshold
    dist.try_step(p.as_ref())
        .unwrap_or_else(|f| panic!("second straggled round failed: {f}"));
    assert_eq!(dist.health().states[straggler], WorkerState::Quarantined);
    let f = dist.last_failure(straggler).expect("failure recorded");
    assert_eq!(f.class, FailureClass::Timeout);
    assert_eq!(f.round, from + 1, "quarantined one round later than the \
         barrier gather would (quorum grace)");
    assert_eq!(dist.health().active_workers, n - 1);

    // the window is over; readmit and the full fleet must settle back in
    dist.try_step(p.as_ref()).unwrap();
    dist.rejoin(straggler).expect("straggler thread is alive");
    for k in 0..6 {
        dist.try_step(p.as_ref())
            .unwrap_or_else(|f| panic!("post-rejoin round {k} failed: {f}"));
    }
    assert_eq!(dist.health().active_workers, n);
    assert!(dist.health().states.iter().all(|s| *s == WorkerState::Active));
    assert!(dist.x().iter().all(|v| v.is_finite()));
}
