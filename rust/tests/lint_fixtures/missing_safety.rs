// Fixture for the `safety-comment` rule (NOT compiled — included as text
// by ../lint.rs): one undocumented `unsafe` that must be flagged, one
// documented `unsafe` that must pass.

/// Reads the first byte without a bounds check.
pub fn first_unchecked(v: &[u8]) -> u8 {
    unsafe { *v.get_unchecked(0) }
}

pub fn first_documented(v: &[u8]) -> u8 {
    // SAFETY: fixture — the caller guarantees `v` is non-empty.
    unsafe { *v.get_unchecked(0) }
}
