// Fixture for the `cluster-keys` rule (NOT compiled — included as text
// by ../lint.rs, checked against a miniature roadmap that documents only
// `prec`): the `warp_factor` read must be flagged.

pub struct ClusterSpec {
    pub prec: u32,
}

impl ClusterSpec {
    pub fn parse(obj: &Value) -> ClusterSpec {
        let prec = obj.get("prec").and_then(Value::as_u32).unwrap_or(64);
        let _undocumented = obj.get("warp_factor");
        ClusterSpec { prec }
    }
}
