// Fixture for the `no-panic` rule (NOT compiled — included as text by
// ../lint.rs, under a coordinator/ path label). Expected findings: one
// `.unwrap()`, one `.expect(`, one `panic!`, and one reason-less
// LINT-ALLOW; the reasoned allow, the non-panicking `_or_default`
// variant and the test-module unwrap are all exempt.

pub fn head(v: &[u64]) -> u64 {
    *v.first().unwrap()
}

pub fn tail(v: &[u64]) -> u64 {
    *v.last().expect("fixture: non-empty")
}

pub fn boom() -> ! {
    panic!("fixture")
}

pub fn allowed_head(v: &[u64]) -> u64 {
    // LINT-ALLOW(no-panic): fixture — a reasoned escape hatch is honored.
    *v.first().unwrap()
}

pub fn reasonless_head(v: &[u64]) -> u64 {
    // LINT-ALLOW(no-panic):
    *v.first().unwrap()
}

pub fn graceful(v: &[u64]) -> u64 {
    v.first().copied().unwrap_or_default()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(super::head(&[1]), [1u64].first().copied().unwrap());
    }
}
