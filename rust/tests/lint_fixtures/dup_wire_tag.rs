//! Fixture for the `wire-tags` rule (NOT compiled — included as text by
//! ../lint.rs). Frame table the rule reads:
//!
//! | byte   | frame  |
//! |--------|--------|
//! | tag 1  | Dense  |
//! | tag 2  | Sparse |
//! | kind 1 | Delta  |

pub const TAG_DENSE: u8 = 1;
pub const TAG_SPARSE: u8 = 2;
/// Reuses byte 1 — the duplicate the rule must catch.
pub const TAG_CLASH: u8 = 1;
/// Byte 9 appears nowhere in the frame table above.
pub const TAG_GHOST: u8 = 9;
pub const DOWN_DELTA: u8 = 1;
