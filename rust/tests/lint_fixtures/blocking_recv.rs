// Fixture for the `blocking-recv` rule (NOT compiled — included as text
// by ../lint.rs, under a coordinator/ path label): the deadline-free
// wait must be flagged; the deadline-bounded one must pass.

use std::sync::mpsc::Receiver;
use std::time::Duration;

pub fn gather_forever(rx: &Receiver<u64>) -> Option<u64> {
    rx.recv().ok()
}

pub fn gather_bounded(rx: &Receiver<u64>) -> Option<u64> {
    rx.recv_timeout(Duration::from_millis(5)).ok()
}
