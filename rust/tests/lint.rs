//! Fixture tests for the in-tree invariant lint (`shiftcomp::lint`, run
//! in CI as the `shiftcomp-lint` binary), plus the clean-tree self-check.
//!
//! The fixtures under `lint_fixtures/` are plain text (Cargo only builds
//! top-level `tests/*.rs`, so they are never compiled); each seeds the
//! exact violation its rule exists to catch, and the tests pin both that
//! the violation fires and that the adjacent compliant pattern does not —
//! a lint that flags everything would also "catch" every fixture.

use shiftcomp::lint;
use std::path::Path;

/// A path label inside the strictest scope: `no-panic` + `blocking-recv`.
const COORD_PATH: &str = "rust/src/coordinator/fixture.rs";

fn rules(violations: &[lint::Violation], rule: &str) -> usize {
    violations.iter().filter(|v| v.rule == rule).count()
}

#[test]
fn missing_safety_comment_is_flagged() {
    let src = include_str!("lint_fixtures/missing_safety.rs");
    let v = lint::lint_source("rust/src/lint_fixture.rs", src);
    assert_eq!(
        rules(&v, "safety-comment"),
        1,
        "exactly the undocumented unsafe must fire: {v:?}"
    );
    // The documented `unsafe` sits on a later line than the flagged one.
    let flagged = v.iter().find(|f| f.rule == "safety-comment").unwrap();
    assert!(
        src.lines().nth(flagged.line - 1).unwrap().contains("unsafe"),
        "finding must anchor on the unsafe line itself"
    );
}

#[test]
fn stray_unwrap_expect_and_panic_are_flagged() {
    let src = include_str!("lint_fixtures/stray_unwrap.rs");
    let v = lint::lint_source(COORD_PATH, src);
    // head's unwrap, tail's expect, boom's panic!, and the reason-less
    // LINT-ALLOW; the reasoned allow, `unwrap_or_default` and the
    // `#[cfg(test)]` unwrap stay silent.
    assert_eq!(rules(&v, "no-panic"), 4, "findings: {v:?}");
    assert!(
        v.iter()
            .any(|f| f.message.contains("without a reason")),
        "the reason-less LINT-ALLOW must itself be a finding: {v:?}"
    );
    // Outside the no-panic scope the same source is clean.
    let outside = lint::lint_source("rust/src/harness/fixture.rs", src);
    assert_eq!(rules(&outside, "no-panic"), 0, "findings: {outside:?}");
}

#[test]
fn duplicate_and_undocumented_wire_tags_are_flagged() {
    let src = include_str!("lint_fixtures/dup_wire_tag.rs");
    let v = lint::check_wire_tags("rust/src/wire_fixture.rs", src);
    assert_eq!(rules(&v, "wire-tags"), 2, "findings: {v:?}");
    assert!(
        v.iter().any(|f| f.message.contains("reuses frame byte 1")),
        "TAG_CLASH must be reported as a duplicate: {v:?}"
    );
    assert!(
        v.iter().any(|f| f.message.contains("missing from the module-doc")),
        "TAG_GHOST must be reported as undocumented: {v:?}"
    );
}

#[test]
fn undocumented_cluster_key_is_flagged() {
    let src = include_str!("lint_fixtures/undocumented_cluster_key.rs");
    let roadmap = "cluster table: | `prec` | value precision of uplink frames |";
    let v = lint::check_cluster_keys("rust/src/config_fixture.rs", src, roadmap);
    assert_eq!(rules(&v, "cluster-keys"), 1, "findings: {v:?}");
    assert!(
        v.iter().any(|f| f.message.contains("warp_factor")),
        "the undocumented key must be named: {v:?}"
    );
}

#[test]
fn blocking_recv_is_flagged_and_recv_timeout_is_not() {
    let src = include_str!("lint_fixtures/blocking_recv.rs");
    let v = lint::lint_source(COORD_PATH, src);
    assert_eq!(rules(&v, "blocking-recv"), 1, "findings: {v:?}");
    // The rule is scoped: the identical source outside coordinator/ passes.
    let outside = lint::lint_source("rust/src/net/fixture.rs", src);
    assert_eq!(rules(&outside, "blocking-recv"), 0, "findings: {outside:?}");
}

/// The self-check CI runs via `cargo run --bin shiftcomp-lint`, wired as
/// a unit test too so a violation fails tier-1 locally before CI.
#[test]
fn whole_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let report = lint::run_repo(&root).expect("lint walk failed");
    assert!(
        report.files_scanned > 20,
        "the walk found only {} files — wrong root?",
        report.files_scanned
    );
    assert!(
        report.violations.is_empty(),
        "the tree must lint clean; findings:\n{}",
        report
            .violations
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
