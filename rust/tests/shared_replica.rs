//! The shared copy-on-write iterate, observed from outside: workers hold
//! no private dense replica (the fleet reads one published Arc snapshot),
//! per-worker divergence travels as a sparse overlay bounded by the EF
//! compressor's residual support, a missed snapshot rotation triggers a
//! clean resync request instead of a silently-corrupt fold, and a
//! quarantined worker readmitted through the rejoin bootstrap reconstructs
//! the logical replica bit-for-bit (checked over the wire via `Inspect`).

use std::sync::Arc;

use shiftcomp::algorithms::Algorithm;
use shiftcomp::compressors::{Compressor, Packet, RandK, TopK, ValPrec};
use shiftcomp::coordinator::runner::test_harness::{round_cmd_gen, spawn_bare_worker};
use shiftcomp::coordinator::{
    ClusterConfig, DistributedRunner, FaultPlan, MethodKind, OverlayPatch, WorkerState,
};
use shiftcomp::problems::{Problem, Ridge};
use shiftcomp::wire::{self, DownKind};

/// Generous gather deadline (see `tests/chaos.rs`): only injected faults
/// can hit it on these microsecond-scale rounds.
const TEST_TIMEOUT_MS: u64 = 1_000;

fn ridge() -> Arc<Ridge> {
    Arc::new(Ridge::paper_default(3))
}

fn diana_cluster(
    p: &Arc<Ridge>,
    q: f64,
    seed: u64,
    local_steps: usize,
    downlink: Option<Box<dyn Compressor>>,
    faults: Option<FaultPlan>,
) -> DistributedRunner {
    let d = p.dim();
    let n = p.n_workers();
    let omega = RandK::with_q(d, q).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, q)) as Box<dyn Compressor>)
        .collect();
    DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Diana {
                alpha: ss.alpha,
                with_c: false,
            },
            gamma: ss.gamma,
            prec: ValPrec::F64,
            seed,
            local_steps,
            downlink,
            faults,
            round_timeout_ms: TEST_TIMEOUT_MS,
            quarantine_after: 1,
            ..Default::default()
        },
    )
}

/// Exact downlink path: every worker computes against the shared snapshot
/// itself. The overlay stays empty every round, no worker reports any
/// private dense iterate bytes, and the fleet-wide `replica_bytes` stat is
/// exactly the publisher's two snapshot slots — independent of both the
/// round and (structurally) the fleet size.
#[test]
fn exact_path_keeps_overlays_empty_and_workers_replica_free() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let mut dist = diana_cluster(&p, 0.4, 101, 1, None, None);
    for k in 0..25 {
        let s = dist.step(p.as_ref());
        let health = dist.health();
        assert_eq!(health.overlay_nnz, vec![0u64; n], "round {k}: exact-path overlay");
        assert_eq!(
            health.replica_bytes,
            vec![0u64; n],
            "round {k}: workers must hold no private dense replica"
        );
        assert_eq!(
            s.replica_bytes,
            2 * d as u64 * 8,
            "round {k}: fleet replica memory must be the two shared snapshot slots"
        );
    }
}

/// `local_steps > 1` is the one legitimate private dense iterate (the τ
/// sub-steps mutate it in place, so it cannot borrow the shared
/// snapshot): every worker reports exactly `d * 8` bytes, and the fleet
/// stat is the two shared slots plus the n local iterates.
#[test]
fn batched_rounds_report_the_local_iterate_and_nothing_more() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let mut dist = diana_cluster(&p, 0.3, 103, 4, None, None);
    for _ in 0..10 {
        dist.step(p.as_ref());
    }
    let health = dist.health();
    assert_eq!(health.replica_bytes, vec![d as u64 * 8; n]);
    assert_eq!(health.overlay_nnz, vec![0u64; n]);
    let s = dist.step(p.as_ref());
    assert_eq!(s.replica_bytes, (2 + n as u64) * d as u64 * 8);
}

/// EF downlink: the only per-replica state beyond the shared snapshot is
/// the overlay, and its support is bounded by the Top-K residual — the K
/// broadcast coordinates cancel exactly in the error accumulator, so at
/// most `d − K` entries survive. All workers share one publication, so
/// the gauges agree across the fleet.
#[test]
fn ef_downlink_overlay_nnz_bounded_by_residual_support() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let keep = 16usize;
    let mut dist = diana_cluster(
        &p,
        0.4,
        107,
        1,
        Some(Box::new(TopK::new(d, keep))),
        None,
    );
    let mut saw_nonzero = false;
    for k in 0..30 {
        dist.step(p.as_ref());
        let health = dist.health();
        for wi in 0..n {
            assert!(
                health.overlay_nnz[wi] <= (d - keep) as u64,
                "round {k} worker {wi}: overlay nnz {} above the residual bound {}",
                health.overlay_nnz[wi],
                d - keep
            );
            assert_eq!(
                health.overlay_nnz[wi], health.overlay_nnz[0],
                "round {k}: all workers install the same publication"
            );
            assert_eq!(health.replica_bytes[wi], 0, "round {k} worker {wi}");
        }
        saw_nonzero |= health.overlay_nnz[0] > 0;
    }
    assert!(
        saw_nonzero,
        "a K={keep} Top-K downlink must leave a nonzero residual overlay at some round"
    );
}

/// A worker that missed a snapshot rotation (generation gap) must decline
/// the round with a resync request — no compute, no failure, thread alive
/// — and resume normally once a contiguous generation arrives.
#[test]
fn generation_gap_triggers_clean_resync_request() {
    let (cmd_tx, up_rx, handle, d) = spawn_bare_worker(0);
    let x0 = vec![0.25f64; d];

    // bootstrap: dense resync frame installs generation 1 unconditionally
    let mut frame = Vec::new();
    wire::encode_down_into(
        DownKind::Resync,
        &Packet::Dense(x0.clone()),
        ValPrec::F64,
        &mut frame,
    );
    cmd_tx
        .send(round_cmd_gen(
            0,
            frame,
            1,
            Arc::new(x0.clone()),
            Arc::new(OverlayPatch::new()),
        ))
        .unwrap();
    let upd = up_rx.recv().unwrap();
    assert!(upd.failure.is_none(), "bootstrap round must succeed");
    assert!(!upd.needs_resync);
    assert!(upd.payload_bits > 0, "bootstrap round must compute");

    // generation 3 after 1: the worker missed a rotation. It must refuse
    // to compute against the stale base and ask for a resync instead.
    let mut delta = Vec::new();
    wire::encode_down_into(
        DownKind::Delta,
        &Packet::Zero { dim: d as u32 },
        ValPrec::F64,
        &mut delta,
    );
    cmd_tx
        .send(round_cmd_gen(
            1,
            delta.clone(),
            3,
            Arc::new(vec![1.0; d]),
            Arc::new(OverlayPatch::new()),
        ))
        .unwrap();
    let upd = up_rx.recv().unwrap();
    assert!(upd.needs_resync, "a generation gap must request a resync");
    assert!(upd.failure.is_none(), "a gap is not a failure");
    assert_eq!(upd.payload_bits, 0, "the worker must not compute on a stale base");
    assert_eq!(upd.wire_bytes, 0);

    // contiguous generation 2 on the retained base: business as usual
    cmd_tx
        .send(round_cmd_gen(
            1,
            delta,
            2,
            Arc::new(x0),
            Arc::new(OverlayPatch::new()),
        ))
        .unwrap();
    let upd = up_rx.recv().unwrap();
    assert!(upd.failure.is_none(), "thread must still answer normally");
    assert!(!upd.needs_resync);
    assert!(upd.payload_bits > 0);

    cmd_tx
        .send(shiftcomp::coordinator::WorkerCommand::Shutdown)
        .unwrap();
    handle.join().expect("worker thread must exit cleanly");
}

/// Quarantine → rejoin on the EF downlink path: the readmission round is a
/// full fleet resync (the bootstrap collapses every overlay), after which
/// the worker's logical replica — materialized from its snapshot + overlay
/// handles over the `Inspect` wire — is bit-equal to the master's mirror,
/// lagged by the one in-flight publication, round after round.
#[test]
fn rejoin_reconstructs_the_logical_replica_bit_equal() {
    let p = ridge();
    let d = p.dim();
    let (straggler, from, window) = (2usize, 5usize, 1usize);
    let mut dist = diana_cluster(
        &p,
        0.4,
        109,
        1,
        Some(Box::new(TopK::with_q(d, 0.25))),
        Some(FaultPlan::new().straggle(straggler, from, window)),
    );
    for _ in 0..from + window {
        dist.step(p.as_ref());
    }
    assert_eq!(dist.health().states[straggler], WorkerState::Quarantined);

    dist.rejoin(straggler).expect("straggler thread is alive");
    let x_boot = dist.x().to_vec();
    dist.step(p.as_ref());
    // the rejoin round resyncs the whole fleet: every replica holds the
    // boot iterate exactly, overlays collapsed
    let snap = dist.worker_snapshot(straggler);
    assert_eq!(snap.x_replica, x_boot, "rejoin bootstrap must deliver x exactly");
    assert_eq!(dist.health().overlay_nnz, vec![0u64; p.n_workers()]);

    // steady state after readmission: the worker's materialized replica
    // tracks the master's EF mirror, lagged by the in-flight publication
    let mut prev_mirror = dist.replica_mirror().unwrap().to_vec();
    for k in 0..8 {
        dist.step(p.as_ref());
        let snap = dist.worker_snapshot(straggler);
        assert_eq!(
            snap.x_replica, prev_mirror,
            "round {k} after rejoin: snapshot + overlay must equal the lagged mirror"
        );
        prev_mirror = dist.replica_mirror().unwrap().to_vec();
    }
}
