//! Compressed-iterates ablation (Theorems 5/6 + Table 1 GDCI row):
//! GDCI neighborhood vs VR-GDCI exact; our steps vs the Chraibi-et-al rate.
//! `cargo bench --bench gdci`

use shiftcomp::util::bench::time_once;

fn main() {
    let (res, _) = time_once("gdci ablation", || {
        shiftcomp::harness::gdci_ablation("results", 42, 60_000)
    });
    println!("— shape checks —");
    for c in &res.curves {
        println!(
            "  {:<18} rounds→1e-8 {:?}  floor {:.2e}",
            c.label, c.rounds_to_tol, c.error_floor
        );
    }
    println!(
        "  note: GDCI's neighborhood radius scales with η, so the Chraibi-rate\n         \x20 run (tiny η) reaches a *deeper* floor but orders of magnitude\n         \x20 slower — compare the error at matched early rounds in the CSVs."
    );
    println!(
        "  (paper: our GDCI complexity κ(1+ω/n) vs previous κ²(1+ω/n); \
         VR-GDCI eliminates the neighborhood entirely)"
    );
}
