//! Regenerates paper Figure 1 (both panels): DIANA vs Rand-DIANA on ridge,
//! Rand-K sweep (left) and Natural-Dithering grid search (right).
//! `cargo bench --bench fig1`

use shiftcomp::util::bench::time_once;

fn main() {
    let rounds = 120_000;
    let (left, _) = time_once("figure 1 left (rand-k)", || {
        shiftcomp::harness::fig1_left("results", 42, rounds)
    });
    let (right, _) = time_once("figure 1 right (natural dithering grid)", || {
        shiftcomp::harness::fig1_right("results", 42, rounds)
    });

    // paper-shape assertions printed as a verdict block
    println!("— shape checks (paper Figure 1) —");
    for q in [0.1, 0.5, 0.9] {
        let d = left.curve(&format!("diana q={q}"));
        let r = left.curve(&format!("rand-diana q={q}"));
        let ratio = |a: Option<u64>, b: Option<u64>| match (a, b) {
            (Some(a), Some(b)) => format!("{:.2}", b as f64 / a as f64),
            _ => "n/a".into(),
        };
        println!(
            "  q={q}: rand-diana/diana advantage — message bits {}× , total bits {}×",
            ratio(r.bits_msg_to_tol, d.bits_msg_to_tol),
            ratio(r.bits_to_tol, d.bits_to_tol),
        );
    }
    println!(
        "  (paper: Rand-DIANA wins at every q on the left panel; DIANA with \
         optimally tuned ND s* can win on the right, Rand-DIANA preferable at s=2)"
    );
    for c in &right.curves {
        println!(
            "  ND {}: bits→tol {:?} floor {:.1e}",
            c.label, c.bits_to_tol, c.error_floor
        );
    }
}
