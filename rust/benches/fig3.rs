//! Regenerates supplementary Figure 3: Rand-DIANA p sweeps across q.
//! `cargo bench --bench fig3`

use shiftcomp::util::bench::time_once;

fn main() {
    let (results, _) = time_once("figure 3 (p sweep × q)", || {
        shiftcomp::harness::fig3("results", 42, 60_000)
    });
    println!("— shape checks (paper Figure 3) —");
    for fig in &results {
        println!("{}:", fig.name);
        for c in &fig.curves {
            println!(
                "  {}: {}  bits→tol {:?}  floor {:.1e}",
                c.label,
                if c.diverged { "DIVERGED" } else { "ok" },
                c.bits_to_tol,
                c.error_floor
            );
        }
    }
}
