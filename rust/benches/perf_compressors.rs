//! L3 micro-benchmarks: compressor + wire throughput on the hot path,
//! including the allocation-free reuse paths (`compress_into`,
//! `encode_into`/`decode_into`, `add_scaled_into`).
//!
//! `cargo bench --bench perf_compressors [-- --smoke]`

use shiftcomp::compressors::{
    Compressor, NaturalCompression, NaturalDithering, Packet, RandK, Ternary, TopK, ValPrec,
};
use shiftcomp::util::bench::{
    bb, bench_maybe_smoke, smoke_mode, write_bench_json, write_csv, JsonScenario,
};
use shiftcomp::util::rng::Pcg64;
use shiftcomp::wire;

fn main() {
    let smoke = smoke_mode();
    let mut rows = Vec::new();
    let mut json = Vec::new();
    let dims: &[usize] = if smoke {
        &[80, 1_000]
    } else {
        &[80, 1_000, 100_000]
    };
    for &d in dims {
        let mut rng = Pcg64::new(1);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let comps: Vec<Box<dyn Compressor>> = vec![
            Box::new(RandK::with_q(d, 0.1)),
            Box::new(TopK::with_q(d, 0.1)),
            Box::new(NaturalDithering::l2(d, 8)),
            Box::new(NaturalCompression::new(d)),
            Box::new(Ternary::new(d)),
        ];
        for c in &comps {
            // allocation-free reuse path (the hot path in the round loop)
            let mut r = Pcg64::new(2);
            let mut pkt = Packet::Zero { dim: d as u32 };
            let stats = bench_maybe_smoke(&format!("compress_into {} d={d}", c.name()), smoke, || {
                c.compress_into(&mut r, bb(&x), &mut pkt);
                bb(&pkt);
            });
            rows.push(format!("{},{},{:.3e}", c.name(), d, stats.median()));

            // allocating path, for the before/after comparison
            let mut r = Pcg64::new(2);
            let stats =
                bench_maybe_smoke(&format!("compress (alloc) {} d={d}", c.name()), smoke, || {
                    bb(c.compress(&mut r, bb(&x)));
                });
            rows.push(format!("alloc-{},{},{:.3e}", c.name(), d, stats.median()));

            // encode+decode roundtrip cost through recycled buffers
            let mut r2 = Pcg64::new(3);
            let pkt = c.compress(&mut r2, &x);
            let mut buf = Vec::new();
            let mut back = Packet::Zero { dim: d as u32 };
            let stats =
                bench_maybe_smoke(&format!("wire roundtrip {} d={d}", c.name()), smoke, || {
                    wire::encode_into(bb(&pkt), ValPrec::F64, &mut buf);
                    wire::decode_into(&buf, &mut back).unwrap();
                    bb(&back);
                });
            rows.push(format!("wire-{},{},{:.3e}", c.name(), d, stats.median()));
        }

        // sparse-aware consumption vs dense decode (Rand-K at 10 %)
        let mut r3 = Pcg64::new(4);
        let pkt = RandK::with_q(d, 0.1).compress(&mut r3, &x);
        let mut out = vec![0.0; d];
        let stats = bench_maybe_smoke(&format!("decode_into rand-k d={d}"), smoke, || {
            pkt.decode_into(bb(&mut out));
        });
        rows.push(format!("decode_into,{},{:.3e}", d, stats.median()));
        let stats = bench_maybe_smoke(&format!("add_scaled_into rand-k d={d}"), smoke, || {
            pkt.add_scaled_into(0.1, bb(&mut out));
        });
        rows.push(format!("add_scaled_into,{},{:.3e}", d, stats.median()));
        if d == *dims.last().unwrap() {
            json.push(JsonScenario::new(
                format!("consume_randk_d{d}"),
                stats.median(),
                Some(pkt.nnz() as f64 / stats.median()),
            ));
        }
    }
    // wide sparse frame: 1000 18-bit indices — the word-at-a-time
    // BitWriter/BitReader hot path (formerly one bit per loop iteration)
    {
        let d = if smoke { 20_000 } else { 200_000 };
        let mut rng = Pcg64::new(9);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let comp = RandK::with_q(d, 0.005);
        let pkt = comp.compress(&mut rng, &x);
        let nnz = pkt.nnz();
        let mut buf = Vec::new();
        let mut back = Packet::Zero { dim: d as u32 };
        let stats = bench_maybe_smoke(
            &format!("wire roundtrip sparse-wide rand-k d={d} k={nnz}"),
            smoke,
            || {
                wire::encode_into(bb(&pkt), ValPrec::F64, &mut buf);
                wire::decode_into(&buf, &mut back).unwrap();
                bb(&back);
            },
        );
        rows.push(format!("wire-sparse-wide,{},{:.3e}", d, stats.median()));
        json.push(JsonScenario::new(
            format!("wire_sparse_wide_d{d}"),
            stats.median(),
            Some(nnz as f64 / stats.median()),
        ));
    }

    // Top-K selection: the O(d) `select_nth_unstable` partial selection
    // inside `TopK::compress_into` vs a sort-based reference (O(d log d)
    // full sort of the index permutation, then take K). Pins the
    // quickselect path's advantage at the wide-sparse operating point.
    {
        let d = if smoke { 20_000 } else { 200_000 };
        let q = 0.005;
        let mut rng = Pcg64::new(12);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let comp = TopK::with_q(d, q);
        let k = comp.k;
        let mut r = Pcg64::new(13);
        let mut pkt = Packet::Zero { dim: d as u32 };
        let select = bench_maybe_smoke(
            &format!("top-k quickselect (compress_into) d={d} k={k}"),
            smoke,
            || {
                comp.compress_into(&mut r, bb(&x), &mut pkt);
                bb(&pkt);
            },
        );
        rows.push(format!("topk-select,{},{:.3e}", d, select.median()));
        json.push(JsonScenario::new(
            format!("topk_select_d{d}"),
            select.median(),
            Some(d as f64 / select.median()),
        ));

        // sort-based reference: same comparator, same output support
        let mut order: Vec<u32> = Vec::new();
        let mut indices: Vec<u32> = Vec::new();
        let sort = bench_maybe_smoke(
            &format!("top-k full-sort reference d={d} k={k}"),
            smoke,
            || {
                order.clear();
                order.extend(0..d as u32);
                order.sort_unstable_by(|&a, &b| {
                    x[b as usize].abs().total_cmp(&x[a as usize].abs())
                });
                indices.clear();
                indices.extend_from_slice(&order[..k]);
                indices.sort_unstable();
                bb(&indices);
            },
        );
        rows.push(format!("topk-sort-ref,{},{:.3e}", d, sort.median()));
        json.push(JsonScenario::new(
            format!("topk_sort_ref_d{d}"),
            sort.median(),
            Some(d as f64 / sort.median()),
        ));
        // same support from both paths (the reference is a correctness
        // cross-check, not just a baseline)
        let Packet::Sparse { indices: sel, .. } = &pkt else {
            panic!("top-k emits sparse packets");
        };
        assert_eq!(sel, &indices, "quickselect and sort disagree on the support");
        println!(
            "  → quickselect is {:.1}× faster than the sort-based reference at d={d}",
            sort.median() / select.median()
        );
    }

    write_csv(
        "results/perf_compressors.csv",
        "name,dim,median_sec_per_iter",
        &rows,
    )
    .expect("csv");
    write_bench_json("results/BENCH_perf.json", &json).expect("json");
    println!("\nwritten: results/perf_compressors.csv + results/BENCH_perf.json");
}
