//! L3 micro-benchmarks: compressor + wire throughput on the hot path.
//! `cargo bench --bench perf_compressors`

use shiftcomp::compressors::{
    Compressor, NaturalCompression, NaturalDithering, RandK, Ternary, TopK, ValPrec,
};
use shiftcomp::util::bench::{bb, bench, write_csv};
use shiftcomp::util::rng::Pcg64;
use shiftcomp::wire;

fn main() {
    let mut rows = Vec::new();
    for &d in &[80usize, 1_000, 100_000] {
        let mut rng = Pcg64::new(1);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let comps: Vec<Box<dyn Compressor>> = vec![
            Box::new(RandK::with_q(d, 0.1)),
            Box::new(TopK::with_q(d, 0.1)),
            Box::new(NaturalDithering::l2(d, 8)),
            Box::new(NaturalCompression::new(d)),
            Box::new(Ternary::new(d)),
        ];
        for c in &comps {
            let name = format!("compress {} d={d}", c.name());
            let mut r = Pcg64::new(2);
            let stats = bench(&name, || {
                bb(c.compress(&mut r, bb(&x)));
            });
            rows.push(format!("{},{},{:.3e}", c.name(), d, stats.median()));

            // encode+decode roundtrip cost
            let mut r2 = Pcg64::new(3);
            let pkt = c.compress(&mut r2, &x);
            let stats = bench(&format!("wire roundtrip {} d={d}", c.name()), || {
                let bytes = wire::encode(bb(&pkt), ValPrec::F64);
                bb(wire::decode(&bytes).unwrap());
            });
            rows.push(format!("wire-{},{},{:.3e}", c.name(), d, stats.median()));
        }
        // decode-into (allocation-free consumer path)
        let mut r3 = Pcg64::new(4);
        let pkt = RandK::with_q(d, 0.1).compress(&mut r3, &x);
        let mut out = vec![0.0; d];
        let stats = bench(&format!("decode_into rand-k d={d}"), || {
            pkt.decode_into(bb(&mut out));
        });
        rows.push(format!("decode_into,{},{:.3e}", d, stats.median()));
    }
    write_csv(
        "results/perf_compressors.csv",
        "name,dim,median_sec_per_iter",
        &rows,
    )
    .expect("csv");
    println!("\nwritten: results/perf_compressors.csv");
}
