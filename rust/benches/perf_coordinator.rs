//! L3 end-to-end round benchmarks: single-process driver vs threaded
//! coordinator, per-round latency and coordinates/second.
//! `cargo bench --bench perf_coordinator`

use std::sync::Arc;

use shiftcomp::algorithms::{Algorithm, DcgdShift};
use shiftcomp::compressors::RandK;
use shiftcomp::coordinator::DistributedRunner;
use shiftcomp::problems::{Problem, Quadratic, Ridge};
use shiftcomp::util::bench::{bench_slow, write_csv};

fn main() {
    let mut rows = Vec::new();

    // paper-sized problem (d = 80, n = 10)
    {
        let p = Ridge::paper_default(1);
        let mut alg = DcgdShift::diana(&p, RandK::with_q(p.dim(), 0.1), None, 1);
        let stats = bench_slow("single-loop diana round (ridge d=80 n=10)", || {
            alg.step(&p);
        });
        rows.push(format!("single_ridge,{:.3e}", stats.median()));

        let pa = Arc::new(Ridge::paper_default(1));
        let mut dist = DistributedRunner::diana(pa.clone(), RandK::with_q(80, 0.1), 1, None);
        let stats = bench_slow("threaded diana round (ridge d=80 n=10)", || {
            dist.step(pa.as_ref());
        });
        rows.push(format!("threaded_ridge,{:.3e}", stats.median()));
    }

    // larger synthetic problem (d = 20k, n = 8) — wide-vector regime
    {
        let d = 20_000;
        let p = Quadratic::random(64, 8, 1.0, 10.0, 2); // spectral part small...
        let _ = p;
        // gradient cost dominated problems hide coordinator costs; use a
        // quadratic of modest dim but a wide compressor dim via ridge-like
        // synthetic: here we time pure compressor+aggregate on d=20k.
        let pq = WideProblem::new(d, 8, 3);
        let mut alg = DcgdShift::diana(&pq, RandK::with_q(d, 0.01), None, 3);
        let stats = bench_slow("single-loop diana round (wide d=20k n=8)", || {
            alg.step(&pq);
        });
        rows.push(format!("single_wide,{:.3e}", stats.median()));
        let rate = (d * 8) as f64 / stats.median();
        println!("  → {rate:.3e} coordinate-compressions/s across the fleet");
        rows.push(format!("single_wide_coords_per_s,{rate:.3e}"));
    }

    write_csv("results/perf_coordinator.csv", "name,median_sec", &rows).expect("csv");
    println!("\nwritten: results/perf_coordinator.csv");
}

/// A cheap synthetic problem with a wide parameter vector: gradient =
/// (x − target) per worker — isolates coordinator overheads from gradient
/// computation.
struct WideProblem {
    d: usize,
    n: usize,
    targets: Vec<Vec<f64>>,
    x_star: Vec<f64>,
    grad_star: Vec<Vec<f64>>,
}

impl WideProblem {
    fn new(d: usize, n: usize, seed: u64) -> Self {
        let mut rng = shiftcomp::util::rng::Pcg64::new(seed);
        let targets: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..d).map(|_| rng.normal()).collect())
            .collect();
        let mut x_star = vec![0.0; d];
        for t in &targets {
            shiftcomp::linalg::axpy(1.0 / n as f64, t, &mut x_star);
        }
        let grad_star = targets
            .iter()
            .map(|t| {
                x_star
                    .iter()
                    .zip(t.iter())
                    .map(|(x, t)| x - t)
                    .collect::<Vec<f64>>()
            })
            .collect();
        Self {
            d,
            n,
            targets,
            x_star,
            grad_star,
        }
    }
}

impl Problem for WideProblem {
    fn dim(&self) -> usize {
        self.d
    }
    fn n_workers(&self) -> usize {
        self.n
    }
    fn local_grad_into(&self, worker: usize, x: &[f64], out: &mut [f64]) {
        for j in 0..self.d {
            out[j] = x[j] - self.targets[worker][j];
        }
    }
    fn local_loss(&self, worker: usize, x: &[f64]) -> f64 {
        0.5 * shiftcomp::linalg::dist_sq(x, &self.targets[worker])
    }
    fn l_i(&self, _worker: usize) -> f64 {
        1.0
    }
    fn l(&self) -> f64 {
        1.0
    }
    fn mu(&self) -> f64 {
        1.0
    }
    fn x_star(&self) -> &[f64] {
        &self.x_star
    }
    fn grad_star(&self, worker: usize) -> &[f64] {
        &self.grad_star[worker]
    }
}
