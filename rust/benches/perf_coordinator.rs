//! L3 end-to-end round benchmarks: single-process driver vs threaded
//! coordinator, per-round latency and coordinates/second, plus the
//! dense-decode vs sparse-aware aggregation comparison that motivates the
//! O(nnz) hot path.
//!
//! `cargo bench --bench perf_coordinator [-- --smoke]`
//!
//! `--smoke` shrinks dimensions/sample counts to fit tier-1 time budgets.
//! Results go to `results/perf_coordinator.csv` and are merged into the
//! machine-readable `results/BENCH_perf.json` (scenario → median sec,
//! coords/s) so the perf trajectory is tracked across PRs.

use std::sync::Arc;

use shiftcomp::algorithms::{Algorithm, DcgdShift};
use shiftcomp::compressors::{Compressor, RandK, TopK, ValPrec};
use shiftcomp::coordinator::{ClusterConfig, DistributedRunner, MethodKind};
use shiftcomp::linalg::{axpy, zero};
use shiftcomp::net::LinkModel;
use shiftcomp::problems::{Problem, Ridge};
use shiftcomp::util::bench::{
    bench_maybe_smoke, smoke_mode, write_bench_json, write_csv, JsonScenario,
};
use shiftcomp::util::rng::Pcg64;

fn main() {
    let smoke = smoke_mode();
    let mut rows = Vec::new();
    let mut json = Vec::new();

    // paper-sized problem (d = 80, n = 10)
    {
        let p = Ridge::paper_default(1);
        let mut alg = DcgdShift::diana(&p, RandK::with_q(p.dim(), 0.1), None, 1);
        let stats = bench_maybe_smoke("single-loop diana round (ridge d=80 n=10)", smoke, || {
            alg.step(&p);
        });
        rows.push(format!("single_ridge,{:.3e}", stats.median()));
        json.push(JsonScenario::new("single_ridge", stats.median(), None));

        let pa = Arc::new(Ridge::paper_default(1));
        let mut dist = DistributedRunner::diana(pa.clone(), RandK::with_q(80, 0.1), 1, None);
        let stats = bench_maybe_smoke("threaded diana round (ridge d=80 n=10)", smoke, || {
            dist.step(pa.as_ref());
        });
        rows.push(format!("threaded_ridge,{:.3e}", stats.median()));
        json.push(JsonScenario::new("threaded_ridge", stats.median(), None));
    }

    // larger synthetic problem — wide-vector regime (gradient is a cheap
    // subtraction, so coordinator overheads dominate)
    {
        let (d, n) = if smoke { (2_000, 8) } else { (20_000, 8) };
        let pq = WideProblem::new(d, n, 3);
        let mut alg = DcgdShift::diana(&pq, RandK::with_q(d, 0.01), None, 3);
        let stats = bench_maybe_smoke(
            &format!("single-loop diana round (wide d={d} n={n})"),
            smoke,
            || {
                alg.step(&pq);
            },
        );
        rows.push(format!("single_wide,{:.3e}", stats.median()));
        let rate = (d * n) as f64 / stats.median();
        println!("  → {rate:.3e} coordinate-compressions/s across the fleet");
        rows.push(format!("single_wide_coords_per_s,{rate:.3e}"));
        json.push(JsonScenario::new(
            format!("single_wide_d{d}n{n}"),
            stats.median(),
            Some(rate),
        ));
    }

    // ------------------------------------------------------- wide-sparse
    // The tentpole scenario: d = 200k, Rand-K at K = 0.5 % (k = 1000),
    // n = 16. End-to-end round latency plus an isolated aggregation
    // comparison: dense decode + axpy (the old master path) vs
    // Packet::add_scaled_into (the sparse-aware path).
    {
        let (d, n) = if smoke { (20_000, 4) } else { (200_000, 16) };
        let q = 0.005;
        let pq = WideProblem::new(d, n, 7);
        let mut alg = DcgdShift::diana(&pq, RandK::with_q(d, q), None, 7);
        let stats = bench_maybe_smoke(
            &format!("single-loop diana round (sparse d={d} K=0.5% n={n})"),
            smoke,
            || {
                alg.step(&pq);
            },
        );
        rows.push(format!("single_sparse_wide,{:.3e}", stats.median()));
        let rate = (d * n) as f64 / stats.median();
        println!("  → {rate:.3e} coords/s end-to-end");
        json.push(JsonScenario::new(
            format!("round_sparse_wide_d{d}n{n}"),
            stats.median(),
            Some(rate),
        ));

        let pa = Arc::new(WideProblem::new(d, n, 7));
        let mut dist = DistributedRunner::diana(pa.clone(), RandK::with_q(d, q), 7, None);
        let stats = bench_maybe_smoke(
            &format!("threaded diana round (sparse d={d} K=0.5% n={n})"),
            smoke,
            || {
                dist.step(pa.as_ref());
            },
        );
        rows.push(format!("threaded_sparse_wide,{:.3e}", stats.median()));
        json.push(JsonScenario::new(
            format!("round_sparse_wide_threaded_d{d}n{n}"),
            stats.median(),
            Some((d * n) as f64 / stats.median()),
        ));

        // isolated aggregation: one fleet of Rand-K packets, two consumers
        let comp = RandK::with_q(d, q);
        let mut rng = Pcg64::new(11);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let pkts: Vec<_> = (0..n).map(|_| comp.compress(&mut rng, &x)).collect();
        let inv_n = 1.0 / n as f64;
        let mut est = vec![0.0; d];
        let mut decoded = vec![0.0; d];

        let dense = bench_maybe_smoke(
            &format!("aggregate dense-decode baseline (d={d} n={n})"),
            smoke,
            || {
                zero(&mut est);
                for pkt in &pkts {
                    pkt.decode_into(&mut decoded);
                    axpy(inv_n, &decoded, &mut est);
                }
            },
        );
        let dense_rate = (d * n) as f64 / dense.median();
        rows.push(format!("agg_dense,{:.3e}", dense.median()));
        json.push(JsonScenario::new(
            format!("agg_dense_d{d}n{n}"),
            dense.median(),
            Some(dense_rate),
        ));

        let sparse = bench_maybe_smoke(
            &format!("aggregate sparse-aware add_scaled (d={d} n={n})"),
            smoke,
            || {
                zero(&mut est);
                for pkt in &pkts {
                    pkt.add_scaled_into(inv_n, &mut est);
                }
            },
        );
        let sparse_rate = (d * n) as f64 / sparse.median();
        rows.push(format!("agg_sparse,{:.3e}", sparse.median()));
        json.push(JsonScenario::new(
            format!("agg_sparse_d{d}n{n}"),
            sparse.median(),
            Some(sparse_rate),
        ));
        println!(
            "  → sparse-aware aggregation speedup: {:.1}× ({:.3e} vs {:.3e} coords/s)",
            sparse_rate / dense_rate,
            sparse_rate,
            dense_rate
        );
    }

    // ------------------------------------------------- delta downlink
    // The tentpole scenario of PR 2: plain DCGD keeps the aggregate
    // n·K-sparse, so the broadcast delta is O(nnz) — measured per-worker
    // downlink bytes/round vs the former dense d·8.
    {
        let (d, n) = if smoke { (20_000, 4) } else { (200_000, 16) };
        let q = 0.005;
        let pa = Arc::new(WideProblem::new(d, n, 9));
        let mut dist = DistributedRunner::dcgd(pa.clone(), RandK::with_q(d, q), 9, None);
        dist.step(pa.as_ref()); // round 0 ships the dense resync
        let mut down_bits = 0u64;
        let mut rounds = 0u64;
        let stats = bench_maybe_smoke(
            &format!("threaded dcgd delta-downlink round (d={d} n={n})"),
            smoke,
            || {
                let s = dist.step(pa.as_ref());
                down_bits += s.bits_down;
                rounds += 1;
            },
        );
        let down_bytes = down_bits as f64 / 8.0 / rounds as f64 / n as f64;
        let dense_bytes = d as f64 * 8.0;
        println!(
            "  → downlink {down_bytes:.0} B/worker/round vs dense {dense_bytes:.0} \
             ({:.1}× smaller)",
            dense_bytes / down_bytes
        );
        rows.push(format!("downlink_delta_bytes_per_worker,{down_bytes:.3e}"));
        json.push(
            JsonScenario::new(
                format!("downlink_delta_dcgd_d{d}n{n}"),
                stats.median(),
                Some((d * n) as f64 / stats.median()),
            )
            .with_down_bytes(down_bytes),
        );
    }

    // ------------------------------------------- EF-compressed downlink
    // PR 3's tentpole scenario: Rand-DIANA on the wide-sparse problem with
    // an aggressive refresh probability, so the learned shifts densify
    // within a couple of rounds and the *exact* delta broadcast collapses
    // to the dense 8d-byte frame. The Top-K error-fed-back downlink keeps
    // the broadcast O(K) through the densification; both configurations
    // are recorded so the ratio is visible in results/BENCH_perf.json.
    {
        let (d, n) = if smoke { (20_000, 4) } else { (200_000, 16) };
        let q = 0.005;
        let pr = 0.5; // refresh often ⇒ shifts (and the exact delta) densify fast
        let omega = RandK::with_q(d, q).omega().unwrap();
        let mk = |downlink: Option<Box<dyn Compressor>>, uplink_ef: bool, seed: u64| {
            let pa = Arc::new(WideProblem::new(d, n, seed));
            // the duplex configuration runs Top-K workers (no ω): γ from
            // the EF-BV rule; the unbiased configurations keep Theorem 4
            let gamma = if uplink_ef {
                let delta = TopK::with_q(d, q).delta().unwrap();
                shiftcomp::theory::ef_uplink(pa.as_ref(), &vec![delta; n]).gamma
            } else {
                shiftcomp::theory::rand_diana(pa.as_ref(), omega, &vec![pr; n], None).gamma
            };
            let qs: Vec<Box<dyn Compressor>> = (0..n)
                .map(|_| {
                    if uplink_ef {
                        Box::new(TopK::with_q(d, q)) as Box<dyn Compressor>
                    } else {
                        Box::new(RandK::with_q(d, q)) as Box<dyn Compressor>
                    }
                })
                .collect();
            let dist = DistributedRunner::new(
                pa.clone(),
                qs,
                None,
                vec![vec![0.0; d]; n],
                ClusterConfig {
                    method: MethodKind::RandDiana { p: pr },
                    gamma,
                    prec: ValPrec::F64,
                    seed,
                    links: None,
                    resync_every: 0,
                    local_steps: 1,
                    pipeline: false,
                    downlink,
                    uplink_ef,
                    ..Default::default()
                },
            );
            (pa, dist)
        };
        let dense_bytes = d as f64 * 8.0;
        let mut results = Vec::new();
        for (label, downlink, uplink_ef) in [
            ("exact", None::<Box<dyn Compressor>>, false),
            (
                "ef_topk",
                Some(Box::new(TopK::with_q(d, q)) as Box<dyn Compressor>),
                false,
            ),
            // the full-duplex EF configuration this PR unlocks: Top-K with
            // worker-side error feedback on the *uplink* too — previously
            // impossible (biased Q was rejected outright)
            (
                "ef_topk_duplex",
                Some(Box::new(TopK::with_q(d, q)) as Box<dyn Compressor>),
                true,
            ),
        ] {
            let (pa, mut dist) = mk(downlink, uplink_ef, 17);
            // warm-up: round-0 resync + enough rounds for the shifts to
            // densify (every worker refreshes w.h.p. within 5 rounds)
            for _ in 0..5 {
                dist.step(pa.as_ref());
            }
            let mut down_bits = 0u64;
            let mut up_bits = 0u64;
            let mut rounds = 0u64;
            let stats = bench_maybe_smoke(
                &format!("rand-diana densified downlink [{label}] (d={d} n={n})"),
                smoke,
                || {
                    let s = dist.step(pa.as_ref());
                    down_bits += s.bits_down;
                    up_bits += s.bits_up;
                    rounds += 1;
                },
            );
            let down_bytes = down_bits as f64 / 8.0 / rounds as f64 / n as f64;
            let up_bytes = up_bits as f64 / 8.0 / rounds as f64 / n as f64;
            println!(
                "  → [{label}] downlink {down_bytes:.0} B/worker/round, uplink {up_bytes:.0} \
                 B/worker/round vs dense {dense_bytes:.0} ({:.1}× / {:.1}× smaller)",
                dense_bytes / down_bytes,
                dense_bytes / up_bytes
            );
            rows.push(format!("downlink_{label}_rand_diana_bytes,{down_bytes:.3e}"));
            rows.push(format!("uplink_{label}_rand_diana_bytes,{up_bytes:.3e}"));
            json.push(
                JsonScenario::new(
                    format!("downlink_{label}_rand_diana_d{d}n{n}"),
                    stats.median(),
                    Some((d * n) as f64 / stats.median()),
                )
                .with_down_bytes(down_bytes)
                .with_up_bytes(up_bytes),
            );
            results.push((label, down_bytes, up_bytes));
        }
        let exact_bytes = results[0].1;
        let ef_bytes = results[1].1;
        println!(
            "  → EF Top-K keeps the densified broadcast {:.1}× below the exact path \
             ({ef_bytes:.0} vs {exact_bytes:.0} B/worker/round; dense frame {dense_bytes:.0} B)",
            exact_bytes / ef_bytes
        );
        let duplex_up = results[2].2;
        println!(
            "  → EF Top-K uplink (duplex) ships {duplex_up:.0} B/worker/round — O(K) vs the \
             {dense_bytes:.0} B dense frame ({:.1}× smaller)",
            dense_bytes / duplex_up
        );
    }

    // --------------------------------------------------- n = 64 fleet
    {
        let (d, n) = if smoke { (5_000, 16) } else { (50_000, 64) };
        let pa = Arc::new(WideProblem::new(d, n, 11));
        let mut dist = DistributedRunner::diana(pa.clone(), RandK::with_q(d, 0.01), 11, None);
        dist.step(pa.as_ref());
        let mut down_bits = 0u64;
        let mut rounds = 0u64;
        let stats = bench_maybe_smoke(
            &format!("threaded diana round (fleet d={d} n={n})"),
            smoke,
            || {
                let s = dist.step(pa.as_ref());
                down_bits += s.bits_down;
                rounds += 1;
            },
        );
        rows.push(format!("fleet_n{n},{:.3e}", stats.median()));
        json.push(
            JsonScenario::new(
                format!("fleet_diana_d{d}n{n}"),
                stats.median(),
                Some((d * n) as f64 / stats.median()),
            )
            .with_down_bytes(down_bits as f64 / 8.0 / rounds as f64 / n as f64),
        );
    }

    // --------------------------------------- heterogeneous-K fleet
    // Worker i keeps K_i coordinates, K geometric from 0.1 % to ~3 % —
    // exercises per-worker packet-shape caches and mixed frame sizes.
    {
        let (d, n) = if smoke { (10_000, 4) } else { (100_000, 16) };
        let pa = Arc::new(WideProblem::new(d, n, 13));
        let ks: Vec<usize> = (0..n)
            .map(|i| {
                let f = 0.001 * 1.25f64.powi(i as i32);
                ((f * d as f64) as usize).clamp(1, d)
            })
            .collect();
        let omegas: Vec<f64> = ks.iter().map(|&k| d as f64 / k as f64 - 1.0).collect();
        let ss = shiftcomp::theory::dcgd_fixed(pa.as_ref(), &omegas);
        let qs: Vec<Box<dyn Compressor>> = ks
            .iter()
            .map(|&k| Box::new(RandK::new(d, k)) as Box<dyn Compressor>)
            .collect();
        let mut dist = DistributedRunner::new(
            pa.clone(),
            qs,
            None,
            vec![vec![0.0; d]; n],
            ClusterConfig {
                method: MethodKind::Fixed,
                gamma: ss.gamma,
                prec: ValPrec::F64,
                seed: 13,
                links: None,
                resync_every: 0,
                local_steps: 1,
                pipeline: false,
                downlink: None,
                uplink_ef: false,
                ..Default::default()
            },
        );
        dist.step(pa.as_ref());
        let stats = bench_maybe_smoke(
            &format!("threaded dcgd round (heterogeneous K, d={d} n={n})"),
            smoke,
            || {
                dist.step(pa.as_ref());
            },
        );
        rows.push(format!("hetero_k,{:.3e}", stats.median()));
        json.push(JsonScenario::new(
            format!("hetero_k_dcgd_d{d}n{n}"),
            stats.median(),
            Some((d * n) as f64 / stats.median()),
        ));
    }

    // --------------------------------------------- f32 wire precision
    {
        let (d, n) = if smoke { (10_000, 4) } else { (100_000, 16) };
        let pa = Arc::new(WideProblem::new(d, n, 15));
        let omega = RandK::with_q(d, 0.005).omega().unwrap();
        let ss = shiftcomp::theory::diana(pa.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
        let qs: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| Box::new(RandK::with_q(d, 0.005)) as Box<dyn Compressor>)
            .collect();
        let mut dist = DistributedRunner::new(
            pa.clone(),
            qs,
            None,
            vec![vec![0.0; d]; n],
            ClusterConfig {
                method: MethodKind::Diana {
                    alpha: ss.alpha,
                    with_c: false,
                },
                gamma: ss.gamma,
                prec: ValPrec::F32,
                seed: 15,
                links: None,
                resync_every: 0,
                local_steps: 1,
                pipeline: false,
                downlink: None,
                uplink_ef: false,
                ..Default::default()
            },
        );
        dist.step(pa.as_ref());
        let mut down_bits = 0u64;
        let mut rounds = 0u64;
        let stats = bench_maybe_smoke(
            &format!("threaded diana round (f32 wire, d={d} n={n})"),
            smoke,
            || {
                let s = dist.step(pa.as_ref());
                down_bits += s.bits_down;
                rounds += 1;
            },
        );
        rows.push(format!("f32_wire,{:.3e}", stats.median()));
        json.push(
            JsonScenario::new(
                format!("f32_wire_diana_d{d}n{n}"),
                stats.median(),
                Some((d * n) as f64 / stats.median()),
            )
            .with_down_bytes(down_bits as f64 / 8.0 / rounds as f64 / n as f64),
        );
    }

    // ----------------------------------- latency-bound local-step batching
    // PR 4's tentpole scenario: tiny frames (Rand-K with K = 16 of d) over
    // a high-latency WAN link (50 ms one way), so the per-round latency
    // term dwarfs transfer and compute. τ = 8 local steps amortize the
    // round trip over 8 gradient sub-steps; the pipelined pricing
    // additionally overlaps sub-step compute with the uplink transfer. All
    // three configurations run the same number of gradient sub-steps;
    // sim_time_sec is recorded per configuration in
    // results/BENCH_perf.json so the wall-clock collapse (≥ 3× required,
    // ~8× by construction) is inspectable per PR. The τ = 1 baseline keeps
    // the historical comm-only pricing (no compute term), which makes the
    // reported ratio *conservative*: adding the baseline's compute could
    // only widen it.
    {
        let (d, n) = if smoke { (2_000, 4) } else { (10_000, 8) };
        let k = 16usize;
        let total_substeps = if smoke { 64 } else { 256 };
        let wan = LinkModel {
            up_bps: 20e6,
            down_bps: 20e6,
            latency: 0.05,
        };
        let omega = d as f64 / k as f64 - 1.0;
        let mk = |tau: usize, pipeline: bool| {
            let pa = Arc::new(WideProblem::new(d, n, 19));
            let ss = shiftcomp::theory::dcgd_fixed(pa.as_ref(), &vec![omega; n]);
            let qs: Vec<Box<dyn Compressor>> = (0..n)
                .map(|_| Box::new(RandK::new(d, k)) as Box<dyn Compressor>)
                .collect();
            let dist = DistributedRunner::new(
                pa.clone(),
                qs,
                None,
                vec![vec![0.0; d]; n],
                ClusterConfig {
                    method: MethodKind::Fixed,
                    gamma: ss.gamma,
                    prec: ValPrec::F64,
                    seed: 19,
                    links: Some(vec![wan; n]),
                    resync_every: 0,
                    local_steps: tau,
                    pipeline,
                    downlink: None,
                    uplink_ef: false,
                    ..Default::default()
                },
            );
            (pa, dist)
        };
        let mut sims = Vec::new();
        for (label, tau, pipe) in [
            ("per_round", 1usize, false),
            ("tau8_staged", 8, false),
            ("tau8_pipelined", 8, true),
        ] {
            let (pa, mut dist) = mk(tau, pipe);
            let rounds = total_substeps / tau;
            let t0 = std::time::Instant::now();
            for _ in 0..rounds {
                dist.step(pa.as_ref());
            }
            let wall = t0.elapsed().as_secs_f64() / rounds as f64;
            let sim = dist.simulated_time();
            println!(
                "latency-bound [{label}] {rounds} rounds × τ={tau}: simulated {sim:.3} s \
                 ({:.4} s / gradient sub-step)",
                sim / total_substeps as f64
            );
            rows.push(format!("latency_bound_{label}_sim_sec,{sim:.3e}"));
            json.push(
                JsonScenario::new(format!("latency_bound_{label}_d{d}n{n}"), wall, None)
                    .with_sim_time(sim),
            );
            sims.push(sim);
        }
        println!(
            "  → τ=8 + pipelining cuts the latency-bound simulated wall clock {:.1}× \
             (staged batching alone {:.1}×)",
            sims[0] / sims[2],
            sims[0] / sims[1]
        );
    }

    // ------------------------------------------------ parallel master fold
    // This PR's tentpole scenario: break the master's own CPU time out of
    // the round wall-clock — broadcast encode + uplink decode + fold, with
    // the gather wait excluded (`DistributedRunner::master_seconds`) — at
    // fold-pool widths T ∈ {1, 4, 8}. Trajectories are bit-identical
    // across T (asserted below): the pool trades master wall-clock only.
    // One `master_secs`-bearing row per (n, T) lands in
    // results/BENCH_perf.json so the scaling is inspectable across PRs.
    // (Measured speedup depends on the host's core count; single-core
    // runners legitimately record ~1×.)
    {
        let q = 0.005;
        let fleets: &[(usize, usize)] = if smoke {
            &[(20_000, 4), (20_000, 8)]
        } else {
            &[(200_000, 16), (200_000, 64)]
        };
        let (warmup, measured) = if smoke { (2u32, 6u32) } else { (5, 30) };
        for &(d, n) in fleets {
            let omega = RandK::with_q(d, q).omega().unwrap();
            let mut final_x: Option<Vec<f64>> = None;
            let mut per_t = Vec::new();
            for t in [1usize, 4, 8] {
                let pa = Arc::new(WideProblem::new(d, n, 21));
                let ss =
                    shiftcomp::theory::diana(pa.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
                let qs: Vec<Box<dyn Compressor>> = (0..n)
                    .map(|_| Box::new(RandK::with_q(d, q)) as Box<dyn Compressor>)
                    .collect();
                let mut dist = DistributedRunner::new(
                    pa.clone(),
                    qs,
                    None,
                    vec![vec![0.0; d]; n],
                    ClusterConfig {
                        method: MethodKind::Diana {
                            alpha: ss.alpha,
                            with_c: false,
                        },
                        gamma: ss.gamma,
                        seed: 21,
                        master_threads: Some(t),
                        ..Default::default()
                    },
                );
                for _ in 0..warmup {
                    dist.step(pa.as_ref());
                }
                let m0 = dist.master_seconds();
                let t0 = std::time::Instant::now();
                for _ in 0..measured {
                    dist.step(pa.as_ref());
                }
                let wall = t0.elapsed().as_secs_f64() / measured as f64;
                let master = (dist.master_seconds() - m0) / measured as f64;
                println!(
                    "master fold (d={d} n={n} T={t}): {master:.3e} s master / {wall:.3e} s round"
                );
                rows.push(format!("master_fold_n{n}_T{t},{master:.3e}"));
                json.push(
                    JsonScenario::new(
                        format!("master_fold_d{d}n{n}_T{t}"),
                        wall,
                        Some((d * n) as f64 / wall),
                    )
                    .with_master_secs(master),
                );
                per_t.push((t, master));
                // bit-identity across pool widths: identical trajectory
                // regardless of T (the fold pool's core invariant)
                match &final_x {
                    None => final_x = Some(dist.x().to_vec()),
                    Some(x1) => assert_eq!(
                        x1.as_slice(),
                        dist.x(),
                        "T={t} trajectory diverged from T=1 at d={d} n={n}"
                    ),
                }
            }
            println!(
                "  → fold pool cuts master time {:.1}× at T=4, {:.1}× at T=8 (n={n}, d={d})",
                per_t[0].1 / per_t[1].1,
                per_t[0].1 / per_t[2].1
            );
        }
    }

    // ------------------------------------------ shared-replica memory
    // This PR's tentpole scenario: the fleet holds ONE iterate. Workers
    // read the master's double-buffered Arc snapshot instead of keeping
    // private dense replicas, so resident replica memory is the two
    // shared slots — 2·d·8 bytes, flat in the fleet size — where the old
    // layout paid n·d·8. Measured at n ∈ {64, 256, 1024}; every fleet
    // size optimizes the *same* homogeneous objective, so the n = 64
    // final iterate doubles as a correctness baseline for the larger
    // fleets (sparsifier streams differ across n, hence a tolerance
    // rather than bit-equality). A Top-K EF-downlink run at the largest
    // fleet bounds the only extra replica state — the sparse overlay —
    // by its residual support. `--smoke` keeps the full n sweep
    // (including the 1024-thread fleet) and shrinks d only.
    {
        let (d, rounds) = if smoke { (2_000, 120) } else { (200_000, 120) };
        let q = 0.25;
        let omega = RandK::with_q(d, q).omega().unwrap();
        let shared_bytes = 2 * d as u64 * 8;
        let mut baseline: Option<Vec<f64>> = None;
        for n in [64usize, 256, 1024] {
            let pa = Arc::new(SharedTargetProblem::new(d, n, 23));
            let ss = shiftcomp::theory::diana(pa.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
            let qs: Vec<Box<dyn Compressor>> = (0..n)
                .map(|_| Box::new(RandK::with_q(d, q)) as Box<dyn Compressor>)
                .collect();
            let mut dist = DistributedRunner::new(
                pa.clone(),
                qs,
                None,
                vec![vec![0.0; d]; n],
                ClusterConfig {
                    method: MethodKind::Diana {
                        alpha: ss.alpha,
                        with_c: false,
                    },
                    gamma: ss.gamma,
                    seed: 23,
                    // a 1024-thread fleet oversubscribes any host; the
                    // gather deadline must not quarantine healthy workers
                    round_timeout_ms: 120_000,
                    ..Default::default()
                },
            );
            let t0 = std::time::Instant::now();
            for k in 0..rounds {
                let s = dist.step(pa.as_ref());
                assert_eq!(
                    s.replica_bytes, shared_bytes,
                    "round {k}, n={n}: exact-path replica memory must be the two shared slots"
                );
            }
            let wall = t0.elapsed().as_secs_f64() / rounds as f64;
            let health = dist.health();
            assert_eq!(
                health.replica_bytes.iter().sum::<u64>(),
                0,
                "n={n}: workers must hold no private dense replica"
            );
            let old_bytes = n as u64 * d as u64 * 8;
            println!(
                "  → replica memory (n={n}): {shared_bytes} B shared vs {old_bytes} B at \
                 n·d·8 per-worker replicas ({:.0}× less)",
                old_bytes as f64 / shared_bytes as f64
            );
            rows.push(format!("replica_bytes_n{n},{:.3e}", shared_bytes as f64));
            json.push(
                JsonScenario::new(
                    format!("replica_memory_d{d}n{n}"),
                    wall,
                    Some((d * n) as f64 / wall),
                )
                .with_replica_bytes(shared_bytes as f64),
            );
            // same objective at every n ⇒ same answer: by round 120 each
            // fleet sits within ~1e-12 of x*, so fleets agree to ~1e-10
            let xs = pa.x_star();
            let xs_norm = shiftcomp::linalg::dist_sq(xs, &vec![0.0; d]).sqrt();
            let err = shiftcomp::linalg::dist_sq(dist.x(), xs).sqrt() / xs_norm;
            assert!(err < 1e-8, "n={n}: final relative error {err:.3e} not converged");
            match &baseline {
                None => baseline = Some(dist.x().to_vec()),
                Some(x64) => {
                    let diff = shiftcomp::linalg::dist_sq(x64, dist.x()).sqrt() / xs_norm;
                    assert!(
                        diff < 1e-8,
                        "n={n}: final iterate {diff:.3e} away from the n=64 baseline"
                    );
                }
            }
        }
        // EF Top-K downlink at the largest fleet: per-replica divergence
        // rides in the published overlay, whose support the K broadcast
        // coordinates are excluded from — so the fleet pays at most the
        // two snapshot slots plus two (4+8)-byte-per-entry patch slots.
        {
            let n = 1024usize;
            let keep = (d / 100).max(1);
            let pa = Arc::new(SharedTargetProblem::new(d, n, 29));
            let ss = shiftcomp::theory::diana(pa.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
            let qs: Vec<Box<dyn Compressor>> = (0..n)
                .map(|_| Box::new(RandK::with_q(d, q)) as Box<dyn Compressor>)
                .collect();
            let mut dist = DistributedRunner::new(
                pa.clone(),
                qs,
                None,
                vec![vec![0.0; d]; n],
                ClusterConfig {
                    method: MethodKind::Diana {
                        alpha: ss.alpha,
                        with_c: false,
                    },
                    gamma: ss.gamma,
                    seed: 29,
                    downlink: Some(Box::new(TopK::new(d, keep))),
                    round_timeout_ms: 120_000,
                    ..Default::default()
                },
            );
            let ef_rounds = rounds / 3;
            let cap = shared_bytes + 2 * (d - keep) as u64 * 12;
            let mut max_bytes = 0u64;
            let t0 = std::time::Instant::now();
            for k in 0..ef_rounds {
                let s = dist.step(pa.as_ref());
                assert!(
                    s.replica_bytes <= cap,
                    "round {k}: EF replica memory {} above the residual-support cap {cap}",
                    s.replica_bytes
                );
                max_bytes = max_bytes.max(s.replica_bytes);
            }
            let wall = t0.elapsed().as_secs_f64() / ef_rounds as f64;
            let health = dist.health();
            let max_nnz = health.overlay_nnz.iter().max().copied().unwrap_or(0);
            assert!(max_nnz <= (d - keep) as u64, "overlay support above the residual bound");
            println!(
                "  → EF Top-K replica memory (n={n}): peak {max_bytes} B shared \
                 (overlay nnz ≤ {max_nnz}) vs {} B at n·d·8",
                n as u64 * d as u64 * 8
            );
            rows.push(format!("replica_bytes_ef_n{n},{:.3e}", max_bytes as f64));
            json.push(
                JsonScenario::new(
                    format!("replica_memory_ef_d{d}n{n}"),
                    wall,
                    Some((d * n) as f64 / wall),
                )
                .with_replica_bytes(max_bytes as f64),
            );
        }
    }

    // ------------------------------------------- quorum straggler collapse
    // This PR's tentpole scenario: a heterogeneous fleet with a 50 ms
    // latency spread (half the workers on 2 ms links, the rest fanned out
    // to 52 ms), fat pipes so the round is latency-bound. The barrier
    // gather prices every round at the slowest link; an m = n/2 quorum
    // close prices it at the m-th fastest arrival — the fast half — and
    // must collapse the simulated wall clock ≥ 3× (≈ 10× by
    // construction). Both runs converge to the same shared-target optimum,
    // so the quorum run's final iterate is required to sit within 1e-6 of
    // the full-participation baseline — bounded staleness costs tail
    // latency, not the answer. sim_time_sec is recorded per configuration
    // in results/BENCH_perf.json so the collapse is inspectable per PR.
    {
        let (d, rounds) = if smoke { (2_000, 120) } else { (20_000, 200) };
        let n = 8usize;
        let q = 0.25;
        let links: Vec<LinkModel> = (0..n)
            .map(|i| LinkModel {
                up_bps: 1e9,
                down_bps: 1e9,
                latency: if i < n / 2 {
                    0.002
                } else {
                    0.022 + 0.01 * (i - n / 2) as f64
                },
            })
            .collect();
        let omega = RandK::with_q(d, q).omega().unwrap();
        let mk = |quorum: Option<usize>, staleness: bool| {
            let pa = Arc::new(SharedTargetProblem::new(d, n, 31));
            let ss = shiftcomp::theory::dcgd_fixed(pa.as_ref(), &vec![omega; n]);
            let qs: Vec<Box<dyn Compressor>> = (0..n)
                .map(|_| Box::new(RandK::with_q(d, q)) as Box<dyn Compressor>)
                .collect();
            let dist = DistributedRunner::new(
                pa.clone(),
                qs,
                None,
                vec![vec![0.0; d]; n],
                ClusterConfig {
                    method: MethodKind::Fixed,
                    gamma: ss.gamma,
                    seed: 31,
                    links: Some(links.clone()),
                    quorum,
                    staleness,
                    ..Default::default()
                },
            );
            (pa, dist)
        };
        let mut sims = Vec::new();
        let mut finals = Vec::new();
        for (label, quorum, staleness) in [
            ("barrier", None, false),
            ("quorum_half", Some(n / 2), true),
        ] {
            let (pa, mut dist) = mk(quorum, staleness);
            let t0 = std::time::Instant::now();
            for _ in 0..rounds {
                dist.step(pa.as_ref());
            }
            let wall = t0.elapsed().as_secs_f64() / rounds as f64;
            let sim = dist.simulated_time();
            println!(
                "straggler fleet [{label}] {rounds} rounds: simulated {sim:.3} s \
                 ({:.4} s / round)",
                sim / rounds as f64
            );
            rows.push(format!("quorum_straggler_{label}_sim_sec,{sim:.3e}"));
            json.push(
                JsonScenario::new(format!("quorum_straggler_{label}_d{d}n{n}"), wall, None)
                    .with_sim_time(sim),
            );
            sims.push(sim);
            let xs = pa.x_star().to_vec();
            finals.push((dist.x().to_vec(), xs));
        }
        let collapse = sims[0] / sims[1];
        println!(
            "  → m = n/2 quorum close collapses the straggler-bound wall clock {collapse:.1}×"
        );
        assert!(
            collapse >= 3.0,
            "acceptance: quorum close must collapse the heterogeneous-fleet wall clock ≥ 3×, \
             got {collapse:.2}×"
        );
        let xs_norm = shiftcomp::linalg::dist_sq(&finals[0].1, &vec![0.0; d]).sqrt();
        let gap = shiftcomp::linalg::dist_sq(&finals[0].0, &finals[1].0).sqrt() / xs_norm;
        assert!(
            gap < 1e-6,
            "acceptance: the quorum run's final iterate must sit within 1e-6 of full \
             participation, gap {gap:.3e}"
        );
    }

    write_csv("results/perf_coordinator.csv", "name,median_sec", &rows).expect("csv");
    write_bench_json("results/BENCH_perf.json", &json).expect("json");
    println!("\nwritten: results/perf_coordinator.csv + results/BENCH_perf.json");
}

/// A cheap synthetic problem with a wide parameter vector: gradient =
/// (x − target) per worker — isolates coordinator overheads from gradient
/// computation.
struct WideProblem {
    d: usize,
    n: usize,
    targets: Vec<Vec<f64>>,
    x_star: Vec<f64>,
    grad_star: Vec<Vec<f64>>,
}

impl WideProblem {
    fn new(d: usize, n: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let targets: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..d).map(|_| rng.normal()).collect())
            .collect();
        let mut x_star = vec![0.0; d];
        for t in &targets {
            shiftcomp::linalg::axpy(1.0 / n as f64, t, &mut x_star);
        }
        let grad_star = targets
            .iter()
            .map(|t| {
                x_star
                    .iter()
                    .zip(t.iter())
                    .map(|(x, t)| x - t)
                    .collect::<Vec<f64>>()
            })
            .collect();
        Self {
            d,
            n,
            targets,
            x_star,
            grad_star,
        }
    }
}

/// Every worker shares one target, so (a) the objective — and hence the
/// final iterate — is independent of the fleet size, making runs at
/// different n directly comparable, and (b) the problem itself stays O(d)
/// resident no matter how many workers mount it. `WideProblem`'s n·d
/// per-worker targets would dwarf the replica memory the shared-replica
/// scenario measures at n = 1024.
struct SharedTargetProblem {
    d: usize,
    n: usize,
    target: Vec<f64>,
    zeros: Vec<f64>,
}

impl SharedTargetProblem {
    fn new(d: usize, n: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let target: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        Self {
            d,
            n,
            target,
            zeros: vec![0.0; d],
        }
    }
}

impl Problem for SharedTargetProblem {
    fn dim(&self) -> usize {
        self.d
    }
    fn n_workers(&self) -> usize {
        self.n
    }
    fn local_grad_into(&self, _worker: usize, x: &[f64], out: &mut [f64]) {
        for j in 0..self.d {
            out[j] = x[j] - self.target[j];
        }
    }
    fn local_loss(&self, _worker: usize, x: &[f64]) -> f64 {
        0.5 * shiftcomp::linalg::dist_sq(x, &self.target)
    }
    fn l_i(&self, _worker: usize) -> f64 {
        1.0
    }
    fn l(&self) -> f64 {
        1.0
    }
    fn mu(&self) -> f64 {
        1.0
    }
    fn x_star(&self) -> &[f64] {
        &self.target
    }
    fn grad_star(&self, _worker: usize) -> &[f64] {
        &self.zeros
    }
}

impl Problem for WideProblem {
    fn dim(&self) -> usize {
        self.d
    }
    fn n_workers(&self) -> usize {
        self.n
    }
    fn local_grad_into(&self, worker: usize, x: &[f64], out: &mut [f64]) {
        for j in 0..self.d {
            out[j] = x[j] - self.targets[worker][j];
        }
    }
    fn local_loss(&self, worker: usize, x: &[f64]) -> f64 {
        0.5 * shiftcomp::linalg::dist_sq(x, &self.targets[worker])
    }
    fn l_i(&self, _worker: usize) -> f64 {
        1.0
    }
    fn l(&self) -> f64 {
        1.0
    }
    fn mu(&self) -> f64 {
        1.0
    }
    fn x_star(&self) -> &[f64] {
        &self.x_star
    }
    fn grad_star(&self, worker: usize) -> &[f64] {
        &self.grad_star[worker]
    }
}
