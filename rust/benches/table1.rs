//! Regenerates paper Table 1: theoretical complexities + measured rounds.
//! `cargo bench --bench table1`

use shiftcomp::harness::{table1, table1::render};
use shiftcomp::util::bench::{time_once, write_csv};

fn main() {
    let eps = 1e-10; // below DCGD's q=0.5 neighborhood: the stalling is visible
    let (rows, _) = time_once("table1 (ridge, rand-k q=0.5)", || {
        table1(42, 0.5, eps, 120_000)
    });
    print!("{}", render(&rows, eps));
    let csv_rows: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{},{},{},{},{:e}",
                r.method.replace(',', ";"),
                r.theory_ours,
                if r.theory_prev.is_nan() {
                    "".to_string()
                } else {
                    format!("{}", r.theory_prev)
                },
                r.measured_rounds.map(|m| m.to_string()).unwrap_or_default(),
                r.floor
            )
        })
        .collect();
    write_csv(
        "results/table1.csv",
        "method,theory_ours,theory_prev,measured_rounds,floor",
        &csv_rows,
    )
    .expect("write results/table1.csv");
    println!("\nwritten: results/table1.csv");
}
