//! Regenerates paper Figure 2: Rand-DIANA stability studies.
//! Left: Lyapunov constant M = b·M'. Right: refresh probability p sweep.
//! `cargo bench --bench fig2`

use shiftcomp::util::bench::time_once;

fn main() {
    let rounds = 60_000;
    let (left, _) = time_once("figure 2 left (M = b·M')", || {
        shiftcomp::harness::fig2_left("results", 42, rounds)
    });
    let (right, _) = time_once("figure 2 right (p sweep at q=0.1)", || {
        shiftcomp::harness::fig2_right("results", 42, rounds)
    });

    println!("— shape checks (paper Figure 2) —");
    for c in &left.curves {
        println!(
            "  {}: {}  floor {:.1e}",
            c.label,
            if c.diverged {
                "DIVERGED"
            } else if c.bits_to_tol.is_some() {
                "converged"
            } else {
                "slow/stalled"
            },
            c.error_floor
        );
    }
    println!("  (paper: b < 1 destabilizes/diverges; b = 1.5 stable but slower)");
    for c in &right.curves {
        println!(
            "  {}: {}  bits→tol {:?}",
            c.label,
            if c.diverged { "DIVERGED" } else { "ok" },
            c.bits_to_tol
        );
    }
    println!("  (paper: smaller p converges in fewer bits; too-large p diverges)");
}
