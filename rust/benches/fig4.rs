//! Regenerates supplementary Figure 4: logistic regression (w2a-like,
//! κ=100) — DIANA vs Rand-DIANA, Rand-K and Natural Dithering.
//! `cargo bench --bench fig4`

use shiftcomp::util::bench::time_once;

fn main() {
    let ((left, right), _) = time_once("figure 4 (logistic w2a)", || {
        shiftcomp::harness::fig4("results", 42, 60_000)
    });
    println!("— shape checks (paper Figure 4) —");
    for q in [0.1, 0.5, 0.9] {
        let d = left.curve(&format!("diana q={q}"));
        let r = left.curve(&format!("rand-diana q={q}"));
        println!(
            "  q={q}: diana bits {:?}, rand-diana bits {:?}",
            d.bits_to_tol, r.bits_to_tol
        );
    }
    println!("  (paper: same conclusions as ridge; DIANA slightly better at q=0.9)");
    for c in &right.curves {
        println!("  ND {}: bits→tol {:?}", c.label, c.bits_to_tol);
    }
}
