//! L2/L1 runtime benchmarks: PJRT execution latency of the AOT artifacts
//! (skips gracefully when `make artifacts` hasn't been run).
//! `cargo bench --bench perf_runtime`

use shiftcomp::runtime::oracles::HloShiftedCompress;
use shiftcomp::runtime::{Engine, HloRidgeOracle, LmSession};
use shiftcomp::util::bench::{bench_slow, write_csv};
use shiftcomp::util::rng::Pcg64;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("no artifacts — run `make artifacts` first; skipping runtime bench");
        return;
    }
    let engine = Engine::cpu("artifacts").expect("engine");
    let mut rows = Vec::new();

    {
        let oracle = HloRidgeOracle::new(&engine).expect("oracle");
        let mut rng = Pcg64::new(1);
        let x: Vec<f64> = (0..oracle.d).map(|_| rng.normal()).collect();
        let a: Vec<f64> = (0..oracle.m_i * oracle.d).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..oracle.m_i).map(|_| rng.normal()).collect();
        let stats = bench_slow("pjrt ridge_grad (10×80)", || {
            oracle.grad(&x, &a, &y, 0.01, 10.0).unwrap();
        });
        rows.push(format!("ridge_grad,{:.3e}", stats.median()));
    }

    {
        let kernel = HloShiftedCompress::new(&engine).expect("kernel");
        let mut rng = Pcg64::new(2);
        let g: Vec<f64> = (0..kernel.d).map(|_| rng.normal()).collect();
        let h: Vec<f64> = (0..kernel.d).map(|_| rng.normal()).collect();
        let mask: Vec<f64> = (0..kernel.d).map(|_| (rng.f64() < 0.1) as u8 as f64).collect();
        let stats = bench_slow("pjrt shifted_compress (d=80)", || {
            kernel.apply(&g, &h, &mask, 10.0).unwrap();
        });
        rows.push(format!("shifted_compress,{:.3e}", stats.median()));
    }

    // §Perf L1/L2 comparison: Pallas-interpret artifact vs XLA-gemm artifact
    for entry in ["lm_step", "lm_step_fast"] {
        if engine.manifest.entry(entry).is_err() {
            continue;
        }
        let session = LmSession::with_entry(&engine, entry).expect("session");
        let params = session.initial_params().expect("params");
        let mut rng = Pcg64::new(3);
        let tokens: Vec<i32> = (0..session.batch * (session.seq + 1))
            .map(|_| rng.below(session.vocab as u64) as i32)
            .collect();
        // warm compile happens inside first call
        let t0 = std::time::Instant::now();
        session.step(&params, &tokens).expect("first step");
        println!(
            "{entry} first call (incl. XLA compile): {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        let mut n = 0u32;
        let t0 = std::time::Instant::now();
        while n < 3 {
            session.step(&params, &tokens).expect("step");
            n += 1;
        }
        let per = t0.elapsed().as_secs_f64() / n as f64;
        println!("{entry} steady state: {per:.3}s / step ({} params)", session.param_count);
        rows.push(format!("{entry},{per:.3e}"));
        // useful-FLOP estimate: ~6 · params · tokens per fwd+bwd
        let tokens_per_step = (session.batch * session.seq) as f64;
        let flops = 6.0 * session.param_count as f64 * tokens_per_step;
        println!(
            "  ≈ {:.2} GFLOP/step → {:.2} GFLOP/s through PJRT CPU",
            flops / 1e9,
            flops / per / 1e9
        );
        rows.push(format!("{entry}_gflops,{:.3}", flops / per / 1e9));
    }

    write_csv("results/perf_runtime.csv", "name,median_sec", &rows).expect("csv");
    println!("\nwritten: results/perf_runtime.csv");
}
